//! Row-major f32 matrix.

use std::fmt;

use crate::util::Pcg32;

/// Dense row-major matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix[{}x{}]", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg32, scale: f32) -> Self {
        let data = (0..rows * cols).map(|_| scale * rng.normal()).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    /// Transpose.
    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// `self @ other` — cache-friendly ikj loop.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self @ other^T` without materializing the transpose.
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            for j in 0..n {
                let brow = other.row(j);
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|a| a * s).collect(),
        }
    }

    /// Hadamard product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Mean squared difference to `other`.
    pub fn mse(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / n as f64
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len().max(1) as f64
    }

    /// Fraction of exact zeros (sparsity of binary planes).
    pub fn zero_fraction(&self) -> f64 {
        let z = self.data.iter().filter(|&&x| x == 0.0).count();
        z as f64 / self.data.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity_property() {
        prop::check(20, |rng| {
            let (m, k) = prop::dims(rng, 24, 400);
            let a = Matrix::randn(m, k, rng, 1.0);
            let i = Matrix::eye(k);
            let c = a.matmul(&i);
            for (x, y) in a.data.iter().zip(&c.data) {
                assert!((x - y).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn matmul_t_consistent() {
        prop::check(20, |rng| {
            let (m, k) = prop::dims(rng, 16, 200);
            let n = rng.range(1, 16);
            let a = Matrix::randn(m, k, rng, 1.0);
            let b = Matrix::randn(n, k, rng, 1.0);
            let c1 = a.matmul_t(&b);
            let c2 = a.matmul(&b.t());
            for (x, y) in c1.data.iter().zip(&c2.data) {
                assert!((x - y).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn transpose_involution() {
        prop::check(10, |rng| {
            let (m, n) = prop::dims(rng, 20, 300);
            let a = Matrix::randn(m, n, rng, 2.0);
            assert_eq!(a.t().t(), a);
        });
    }

    #[test]
    fn zero_fraction_counts() {
        let m = Matrix::from_vec(1, 4, vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(m.zero_fraction(), 0.5);
    }

    #[test]
    fn mse_zero_for_self() {
        let mut rng = Pcg32::seeded(1);
        let a = Matrix::randn(5, 7, &mut rng, 3.0);
        assert_eq!(a.mse(&a), 0.0);
    }
}
