//! Dense linear algebra for the GPTQ path: Cholesky factorization,
//! triangular solves, PSD inversion with dampening.

use anyhow::{bail, Result};

use super::Matrix;

/// Lower-triangular Cholesky factor L with A = L·Lᵀ.
/// Fails if A is not (numerically) positive definite.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    assert_eq!(a.rows, a.cols, "cholesky needs square");
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (sum {sum:.3e})");
                }
                *l.at_mut(i, j) = sum.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Solve L·y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l.at(i, k) as f64 * y[k] as f64;
        }
        y[i] = (sum / l.at(i, i) as f64) as f32;
    }
    y
}

/// Solve Lᵀ·x = y (backward substitution), L lower-triangular.
pub fn solve_lower_t(l: &Matrix, y: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut sum = y[i] as f64;
        for k in i + 1..n {
            sum -= l.at(k, i) as f64 * x[k] as f64;
        }
        x[i] = (sum / l.at(i, i) as f64) as f32;
    }
    x
}

/// Inverse of a PSD matrix via Cholesky: A⁻¹ = L⁻ᵀ·L⁻¹.
pub fn inverse_psd(a: &Matrix) -> Result<Matrix> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for c in 0..n {
        e[c] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for r in 0..n {
            *inv.at_mut(r, c) = x[r];
        }
        e[c] = 0.0;
    }
    Ok(inv)
}

/// Add `lambda * mean(diag)` dampening to the diagonal (GPTQ §3).
pub fn dampen(a: &mut Matrix, lambda: f64) {
    let n = a.rows;
    let mean_diag: f64 = (0..n).map(|i| a.at(i, i) as f64).sum::<f64>() / n as f64;
    let eps = (lambda * mean_diag).max(1e-10) as f32;
    for i in 0..n {
        *a.at_mut(i, i) += eps;
    }
}

/// Upper-triangular Cholesky of the *inverse*: the exact factor GPTQ's
/// error-compensation loop walks.  Returns U with A⁻¹ = Uᵀ·U? — GPTQ uses
/// `Cholesky(H⁻¹, upper=True)`, i.e. A⁻¹ = UᵀU with U upper.  We compute
/// L from A⁻¹ = L·Lᵀ and return U = Lᵀ.
pub fn cholesky_inverse_upper(a: &Matrix) -> Result<Matrix> {
    let inv = inverse_psd(a)?;
    let l = cholesky(&inv)?;
    Ok(l.t())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Pcg32};

    fn random_spd(rng: &mut Pcg32, n: usize) -> Matrix {
        let b = Matrix::randn(n, n, rng, 1.0);
        let mut a = b.matmul_t(&b); // B·Bᵀ is PSD
        for i in 0..n {
            *a.at_mut(i, i) += n as f32 * 0.1; // make strictly PD
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        prop::check(15, |rng| {
            let n = rng.range(1, 24);
            let a = random_spd(rng, n);
            let l = cholesky(&a).unwrap();
            let back = l.matmul_t(&l);
            for (x, y) in a.data.iter().zip(&back.data) {
                assert!((x - y).abs() < 1e-2 * a.abs_max().max(1.0), "{x} vs {y}");
            }
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1, 3
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn inverse_psd_property() {
        prop::check(15, |rng| {
            let n = rng.range(1, 16);
            let a = random_spd(rng, n);
            let inv = inverse_psd(&a).unwrap();
            let prod = a.matmul(&inv);
            let eye = Matrix::eye(n);
            for (x, y) in prod.data.iter().zip(&eye.data) {
                assert!((x - y).abs() < 5e-3, "{x} vs {y}");
            }
        });
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Pcg32::seeded(9);
        let a = random_spd(&mut rng, 8);
        let l = cholesky(&a).unwrap();
        let b: Vec<f32> = (0..8).map(|i| i as f32 + 1.0).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // check A·x = b
        for i in 0..8 {
            let mut acc = 0.0f64;
            for j in 0..8 {
                acc += a.at(i, j) as f64 * x[j] as f64;
            }
            assert!((acc - b[i] as f64).abs() < 1e-2, "row {i}: {acc} vs {}", b[i]);
        }
    }

    #[test]
    fn dampen_increases_diag() {
        let mut rng = Pcg32::seeded(10);
        let mut a = random_spd(&mut rng, 5);
        let before: Vec<f32> = (0..5).map(|i| a.at(i, i)).collect();
        dampen(&mut a, 0.01);
        for i in 0..5 {
            assert!(a.at(i, i) > before[i]);
        }
    }

    #[test]
    fn cholesky_inverse_upper_shape() {
        let mut rng = Pcg32::seeded(11);
        let a = random_spd(&mut rng, 6);
        let u = cholesky_inverse_upper(&a).unwrap();
        // upper-triangular: below-diagonal entries are 0
        for r in 0..6 {
            for c in 0..r {
                assert_eq!(u.at(r, c), 0.0);
            }
        }
        // UᵀU == A⁻¹
        let inv = inverse_psd(&a).unwrap();
        let back = u.t().matmul(&u);
        for (x, y) in inv.data.iter().zip(&back.data) {
            assert!((x - y).abs() < 5e-3);
        }
    }
}
