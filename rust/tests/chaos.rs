//! Chaos: deterministic fault injection across the supervised serving
//! stack.  A seeded [`FaultPlan`] scripts engine failures, worker
//! panics, admission denials, and slow ticks by call ordinal; these
//! tests assert the supervision contract under that fire:
//!
//!   - every request gets exactly one reply — no hangs, no doubles —
//!     across multi-seed soaks,
//!   - uninjected requests decode bit-identically to a fault-free run
//!     (the scripted engine is a pure function of the prompt),
//!   - the server survives repeated worker panics: panicking workers
//!     are respawned, their slots quarantined, and serving continues,
//!   - a chaos run over a real [`NativeEngine`] leaks zero KV blocks,
//!   - a poisoned queue lock and dropped reply receivers degrade to
//!     counters, never to a wedged worker,
//!
//! and the same holds end to end over TCP with connection hardening
//! enabled.  Everything here is deterministic: plans are seeded,
//! workloads are pre-queued, and decode is greedy.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use db_llm::coordinator::chaos::{ChaosEngine, FaultPlan};
use db_llm::coordinator::metrics::Metrics;
use db_llm::coordinator::scheduler::{
    serve_continuous_with, supervised_scheduler_loop, Job, Scheduler, SchedulerConfig, SlotEngine,
    WallClock,
};
use db_llm::coordinator::serve::{ConnConfig, DecodeParams, Request, Response, SharedQueue};
use db_llm::infer::NativeEngine;
use db_llm::model::{ModelConfig, Weights};

const VOCAB: usize = 64;

/// Flake-detector hook: when `DBLLM_TRANSCRIPT_DUMP` names a file,
/// append every seeded transcript line to it.  CI runs the suite twice
/// single-threaded and byte-diffs the two dumps, so any nondeterminism
/// in the seeded soaks surfaces as a diff even when both runs pass.
fn dump_transcript(tag: &str, lines: impl IntoIterator<Item = String>) {
    let Ok(path) = std::env::var("DBLLM_TRANSCRIPT_DUMP") else { return };
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .expect("transcript dump file must be writable");
    for l in lines {
        writeln!(f, "{tag}: {l}").expect("transcript dump write");
    }
}

/// Deterministic scripted engine: logits always peak at
/// `prompt[0] % VOCAB`, so a greedy request for key `k` decodes exactly
/// `[k; budget]`.  Output is a pure function of the prompt, which makes
/// "uninjected requests are bit-identical" assertable with no ordinal
/// bookkeeping.
struct ScriptGen {
    active: Vec<Option<u32>>,
}

impl ScriptGen {
    fn new(slots: usize) -> ScriptGen {
        ScriptGen { active: vec![None; slots] }
    }

    fn peak(key: u32) -> Vec<f32> {
        let mut logits = vec![0.0f32; VOCAB];
        logits[key as usize % VOCAB] = 1.0;
        logits
    }
}

impl SlotEngine for ScriptGen {
    fn slots(&self) -> usize {
        self.active.len()
    }

    fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> anyhow::Result<Vec<f32>> {
        let key = prompt[0];
        self.active[slot] = Some(key);
        Ok(Self::peak(key))
    }

    fn step_slot(&mut self, slot: usize, _token: u32) -> anyhow::Result<Vec<f32>> {
        let key = self.active[slot].expect("step on an empty slot");
        Ok(Self::peak(key))
    }

    fn reset_slot(&mut self, slot: usize) {
        self.active[slot] = None;
    }
}

/// Build one wire-shaped request (reply channel + queue-depth
/// reservation, the accept loop's bookkeeping).
fn wire_request(key: u32, budget: usize, metrics: &Metrics) -> (Request, mpsc::Receiver<Response>) {
    let (tx, rx) = mpsc::channel();
    metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
    (
        Request {
            prompt: vec![key],
            params: DecodeParams::greedy(budget),
            reply: tx,
            arrived: Instant::now(),
            timeout_ms: None,
        },
        rx,
    )
}

/// One full soak under `FaultPlan::random(seed, ..)`: pre-queue 24
/// requests, run the supervised worker to completion, and return every
/// reply (keyed, in submit order) plus the supervision counters.
/// Pre-queuing the whole workload before the worker starts makes the
/// decode order — and so the fault→request mapping — a pure function of
/// the plan, which is what lets the caller replay a seed and demand a
/// bit-identical transcript.
#[allow(clippy::type_complexity)]
fn run_soak(seed: u64) -> (Vec<(u32, Result<Vec<u32>, String>)>, u64, u64, u64) {
    let plan = FaultPlan::random(seed, 160, 3);
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let queue = Arc::new(SharedQueue::new());
    let engine = ChaosEngine::new(ScriptGen::new(2), plan);

    let mut replies = Vec::new();
    for k in 1..=24u32 {
        let (req, rx) = wire_request(k, 4, &metrics);
        assert!(queue.push(req).is_ok(), "queue must be open");
        replies.push((k, rx));
    }
    let worker = {
        let (q, m, r) = (queue.clone(), metrics.clone(), running.clone());
        std::thread::spawn(move || {
            supervised_scheduler_loop(
                engine,
                q,
                SchedulerConfig { slots: 2, seed, trace: true, ..SchedulerConfig::default() },
                m,
                r,
                64,
            )
        })
    };

    let mut transcript = Vec::new();
    for (k, rx) in replies {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|_| panic!("seed {seed}: request {k} hung or was dropped"));
        assert!(rx.try_recv().is_err(), "seed {seed}: request {k} answered twice");
        let summary = match resp.error {
            Some(e) => Err(e),
            None => Ok(resp.tokens),
        };
        transcript.push((k, summary));
    }

    running.store(false, Ordering::Relaxed);
    queue.close();
    worker.join().expect("the supervised worker must never propagate a panic");
    let ord = Ordering::Relaxed;
    dump_transcript(
        &format!("chaos seed={seed}"),
        transcript.iter().map(|(k, r)| format!("k={k} {r:?}")).chain(std::iter::once(format!(
            "counters panics={} respawns={} quarantined={}",
            metrics.worker_panics.load(ord),
            metrics.respawns.load(ord),
            metrics.quarantined_slots.load(ord),
        ))),
    );
    (
        transcript,
        metrics.worker_panics.load(ord),
        metrics.respawns.load(ord),
        metrics.quarantined_slots.load(ord),
    )
}

/// ≥6-seed chaos soak: exactly one reply per request, uninjected
/// requests bit-identical to the fault-free script, and a full replay
/// of every seed reproduces the identical transcript and supervision
/// counters.
#[test]
fn seeded_soak_exactly_once_and_deterministic() {
    let mut total_injected = 0u64;
    for seed in 0..6u64 {
        let (first, panics, respawns, quarantined) = run_soak(seed);
        let (replay, panics2, respawns2, quarantined2) = run_soak(seed);
        assert_eq!(first, replay, "seed {seed}: replay diverged from the first run");
        assert_eq!(
            (panics, respawns, quarantined),
            (panics2, respawns2, quarantined2),
            "seed {seed}: supervision counters diverged on replay"
        );
        // budget 64 is never hit, so every panic earns a respawn
        assert_eq!(respawns, panics, "seed {seed}: a panic went unrespawned");
        for (k, reply) in &first {
            match reply {
                Ok(tokens) => assert_eq!(
                    tokens,
                    &vec![*k; 4],
                    "seed {seed}: uninjected request {k} must match the fault-free script"
                ),
                Err(e) => {
                    assert!(
                        e.contains("chaos") || e.contains("panicked"),
                        "seed {seed}: request {k} failed outside the plan: {e}"
                    );
                    total_injected += 1;
                }
            }
        }
        total_injected += panics;
    }
    assert!(total_injected > 0, "six seeds injected nothing — the harness is a no-op");
}

/// The headline robustness claim: a worker that panics ≥3 times is
/// respawned each time, each panic quarantines the slot it fired in,
/// every in-flight request is answered, and the server keeps serving
/// clean requests afterwards.
#[test]
fn survives_repeated_worker_panics_and_keeps_serving() {
    // ordinals 6 apart: at ≤3 row-steps per 3-token request, no two
    // panics can land inside the same request
    let plan = FaultPlan {
        panic_at_step: [1u64, 7, 13].into_iter().collect(),
        ..FaultPlan::none()
    };
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let queue = Arc::new(SharedQueue::new());
    let engine = ChaosEngine::new(ScriptGen::new(1), plan);

    let mut replies = Vec::new();
    for k in 1..=8u32 {
        let (req, rx) = wire_request(k, 3, &metrics);
        assert!(queue.push(req).is_ok());
        replies.push((k, rx));
    }
    let worker = {
        let (q, m, r) = (queue.clone(), metrics.clone(), running.clone());
        std::thread::spawn(move || {
            supervised_scheduler_loop(
                engine,
                q,
                SchedulerConfig { slots: 1, ..SchedulerConfig::default() },
                m,
                r,
                8,
            )
        })
    };

    let mut panicked = 0usize;
    for (k, rx) in replies {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|_| panic!("request {k} hung across respawns"));
        assert!(rx.try_recv().is_err(), "request {k} answered twice");
        match resp.error {
            Some(e) => {
                assert!(e.contains("worker panicked"), "request {k}: {e}");
                panicked += 1;
            }
            None => assert_eq!(resp.tokens, vec![k; 3], "request {k} decoded wrong"),
        }
    }
    assert_eq!(panicked, 3, "exactly the three scripted panics may claim victims");

    // still serving after three panics
    let (req, rx) = wire_request(9, 3, &metrics);
    assert!(queue.push(req).is_ok());
    let resp = rx.recv_timeout(Duration::from_secs(30)).expect("post-chaos request hung");
    assert!(resp.error.is_none(), "post-chaos request failed: {:?}", resp.error);
    assert_eq!(resp.tokens, vec![9; 3]);

    running.store(false, Ordering::Relaxed);
    queue.close();
    worker.join().expect("worker must exit cleanly");
    let ord = Ordering::Relaxed;
    assert_eq!(metrics.worker_panics.load(ord), 3);
    assert_eq!(metrics.respawns.load(ord), 3);
    assert_eq!(metrics.quarantined_slots.load(ord), 3);
}

/// Chaos over a real `NativeEngine`: scripted prefill failures, a step
/// failure, and a mid-decode panic, driven through the scheduler core
/// with the supervisor's own recovery sequence.  After the storm the
/// idle engine must hold zero live KV blocks — quarantine and recovery
/// reclaimed everything — and the pool's internal audit must pass.
#[test]
fn native_engine_chaos_reclaims_every_kv_block() {
    let cfg = ModelConfig {
        name: "t".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 192,
        vocab: 96,
        seq_len: 32,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    };
    let native =
        NativeEngine::new(Weights::synthetic(&cfg, 7), &BTreeMap::new(), cfg.seq_len, 42)
            .with_slots(2);
    let pool = native.kv_pool().clone();
    // the panic ordinal comes last: recovery drains the whole core
    // queue, so the failure flavors must fire before it to be exercised
    let plan = FaultPlan {
        prefill_fail: [1u64].into_iter().collect(),
        step_fail: [2u64].into_iter().collect(),
        panic_at_step: [5u64].into_iter().collect(),
        ..FaultPlan::none()
    };
    let mut core = Scheduler::new(
        ChaosEngine::new(native, plan),
        WallClock::default(),
        SchedulerConfig { slots: 2, ..SchedulerConfig::default() },
    );
    for k in 0..8u32 {
        core.submit(Job {
            prompt: vec![k % 96, (k + 1) % 96, (k + 2) % 96],
            params: DecodeParams::greedy(2),
            timeout_ms: None,
            queued_for_ms: 0,
        });
    }

    let (mut done, mut panics) = (0usize, 0usize);
    for _ in 0..10_000 {
        if done >= 8 {
            break;
        }
        match catch_unwind(AssertUnwindSafe(|| core.tick())) {
            Ok(completions) => done += completions.len(),
            Err(_) => {
                panics += 1;
                let (dead, quarantined) = core.recover_after_panic("worker panicked: chaos");
                assert!(quarantined > 0, "a mid-decode panic must quarantine its slot");
                done += dead.len();
                core.engine_mut().recover().expect("engine recovery after a scripted panic");
            }
        }
    }
    assert_eq!(done, 8, "every submitted job must complete exactly once");
    assert!(panics >= 1, "the scripted panic never fired");
    assert_eq!(pool.stats().live_blocks, 0, "chaos leaked KV blocks");
    pool.assert_invariants();
}

/// A poisoned queue lock and a client that vanished before its reply
/// both degrade gracefully: the worker repairs the lock (counted in
/// `queue_lock_poisoned`), drops the dead reply send, and keeps
/// serving — no panic, no wedge.
#[test]
fn queue_poison_and_dropped_receivers_do_not_wedge_the_worker() {
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let queue = Arc::new(SharedQueue::new());
    queue.poison_for_chaos();

    // this client disconnected before its reply could be delivered
    let (req, dead_rx) = wire_request(5, 3, &metrics);
    drop(dead_rx);
    assert!(queue.push(req).is_ok());
    let (req, rx) = wire_request(6, 3, &metrics);
    assert!(queue.push(req).is_ok());

    let worker = {
        let (q, m, r) = (queue.clone(), metrics.clone(), running.clone());
        std::thread::spawn(move || {
            supervised_scheduler_loop(
                ScriptGen::new(1),
                q,
                SchedulerConfig { slots: 1, ..SchedulerConfig::default() },
                m,
                r,
                8,
            )
        })
    };
    let resp = rx.recv_timeout(Duration::from_secs(30)).expect("live request hung");
    assert!(resp.error.is_none());
    assert_eq!(resp.tokens, vec![6; 3]);

    running.store(false, Ordering::Relaxed);
    queue.close();
    worker.join().expect("worker must survive poison + dead receivers");
    let ord = Ordering::Relaxed;
    assert!(metrics.queue_lock_poisoned.load(ord) >= 1, "poison recovery went uncounted");
    assert_eq!(metrics.worker_panics.load(ord), 0, "poison must not look like a panic");
}

/// End to end over TCP with connection hardening on: scripted panics
/// behind a live socket, a client that disconnects mid-request, and the
/// stats surface reporting the carnage — while the server keeps
/// answering.
#[test]
fn tcp_chaos_survives_panics_and_disconnects() {
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let conn = ConnConfig {
        read_timeout: Some(Duration::from_millis(200)),
        write_timeout: Some(Duration::from_secs(5)),
        max_line_bytes: 1 << 16,
        idle_timeout: Some(Duration::from_secs(30)),
    };
    // ordinals 6 apart: exactly two panics land inside the 6-request
    // workload below, each in its own request
    let addr = serve_continuous_with(
        || {
            let plan = FaultPlan {
                panic_at_step: [2u64, 8].into_iter().collect(),
                ..FaultPlan::none()
            };
            Ok(ChaosEngine::new(ScriptGen::new(1), plan))
        },
        "127.0.0.1:0",
        64,
        SchedulerConfig { slots: 1, ..SchedulerConfig::default() },
        1,
        metrics.clone(),
        running.clone(),
        conn,
        8,
    )
    .unwrap();

    let mut stream = loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) => std::thread::sleep(Duration::from_millis(50)),
        }
    };
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut clean = 0usize;
    let mut errored = 0usize;
    for k in 1..=6u32 {
        writeln!(stream, "{{\"prompt\": [{k}], \"max_tokens\": 3}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        if line.contains("\"error\"") {
            assert!(line.contains("panicked"), "request {k}: unexpected error line {line}");
            errored += 1;
        } else {
            let j = db_llm::util::Json::parse(line.trim()).unwrap();
            assert_eq!(j.usize_list("tokens").unwrap(), vec![k as usize; 3]);
            clean += 1;
        }
    }
    assert_eq!(errored, 2, "exactly the two scripted panics reach the wire");
    assert_eq!(clean, 4);

    // a client that sends a request and vanishes must not hurt anyone
    {
        let mut ghost = std::net::TcpStream::connect(addr).unwrap();
        writeln!(ghost, "{{\"prompt\": [7], \"max_tokens\": 3}}").unwrap();
        // dropped here: the reply write fails server-side, harmlessly
    }

    // still serving on a fresh connection after panics + disconnect
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "{{\"prompt\": [9], \"max_tokens\": 3}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = db_llm::util::Json::parse(line.trim()).unwrap();
    assert_eq!(j.usize_list("tokens").unwrap(), vec![9usize; 3]);

    // the supervision counters are on the live stats surface
    writeln!(stream, "{{\"cmd\": \"stats\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"worker_panics\":2"), "stats surface missing panics: {line}");
    assert!(line.contains("\"respawns\":2"), "stats surface missing respawns: {line}");

    running.store(false, Ordering::Relaxed);
    let ord = Ordering::Relaxed;
    assert_eq!(metrics.worker_panics.load(ord), 2);
    assert_eq!(metrics.respawns.load(ord), 2);
    assert_eq!(metrics.quarantined_slots.load(ord), 2);
}
