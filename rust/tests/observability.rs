//! Observability suite: the wire-level `{"cmd":"stats"}` surface
//! (JSON + Prometheus round-trip, counter monotonicity), phase-level
//! TTFT / inter-token histograms pinned against a `ManualClock`
//! scheduler sim with known per-tick timings, request-span lifecycle
//! records, bounded trace rings, and — the acceptance gate — proof
//! that tracing + per-tick profiling never changes a decoded stream.
//! Artifact-free: scripted engines and synthetic weights only.

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use db_llm::coordinator::scheduler::{
    serve_continuous, Clock, Completion, FinishReason, Job, ManualClock, Scheduler,
    SchedulerConfig, SlotEngine,
};
use db_llm::coordinator::serve::{DecodeParams, Generator};
use db_llm::infer::NativeEngine;
use db_llm::model::{ModelConfig, Weights};
use db_llm::util::Json;

const EOS: u32 = 63;
const VOCAB: usize = 64;

/// Scripted engine (same shape as tests/scheduler_sim.rs): a request is
/// keyed by `prompt[0]` and emits its key for the scripted number of
/// content tokens, then EOS.
struct MockGen {
    slots: usize,
    script: BTreeMap<u32, usize>,
    state: Vec<Option<(u32, usize)>>,
}

impl MockGen {
    fn new(slots: usize, script: &[(u32, usize)]) -> MockGen {
        MockGen {
            slots,
            script: script.iter().copied().collect(),
            state: (0..slots).map(|_| None).collect(),
        }
    }

    fn logits(&self, key: u32, emitted: usize) -> Vec<f32> {
        let n = self.script[&key];
        let mut l = vec![0.0f32; VOCAB];
        let target = if emitted >= n { EOS } else { key };
        l[target as usize] = 10.0;
        l
    }
}

impl SlotEngine for MockGen {
    fn slots(&self) -> usize {
        self.slots
    }

    fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let key = prompt[0];
        self.state[slot] = Some((key, 0));
        Ok(self.logits(key, 0))
    }

    fn step_slot(&mut self, slot: usize, _token: u32) -> anyhow::Result<Vec<f32>> {
        let (key, emitted) = self.state[slot].expect("step on a slot without prefill");
        self.state[slot] = Some((key, emitted + 1));
        Ok(self.logits(key, emitted + 1))
    }

    fn step_slots_atomic(&self) -> bool {
        true
    }

    fn reset_slot(&mut self, slot: usize) {
        self.state[slot] = None;
    }
}

fn job(key: u32, max_tokens: usize) -> Job {
    Job {
        prompt: vec![key],
        params: DecodeParams { stop: Some(EOS), ..DecodeParams::greedy(max_tokens) },
        timeout_ms: None,
        queued_for_ms: 0,
    }
}

fn drain<E: SlotEngine, C: Clock>(core: &mut Scheduler<E, C>) -> Vec<Completion> {
    let mut out = Vec::new();
    let mut guard = 0;
    while !core.is_idle() {
        out.extend(core.tick());
        core.assert_invariants();
        guard += 1;
        assert!(guard < 100_000, "scheduler failed to drain");
    }
    out
}

/// Known per-tick timings on the virtual clock pin the TTFT, queue-wait
/// and inter-token distributions *exactly*: 10 ms of queue wait lands
/// in the [8192, 16384) µs bucket (geometric mean 11585), and 3 ms
/// between decode ticks lands every ITL sample in [2048, 4096) µs
/// (geometric mean 2896).
#[test]
fn ttft_and_itl_histograms_match_scripted_clock() {
    let gen = MockGen::new(1, &[(1, 100)]);
    let clock = ManualClock::default();
    let cfg = SchedulerConfig { slots: 1, trace: true, ..Default::default() };
    let mut core = Scheduler::new(gen, clock.clone(), cfg);
    let id = core.submit(job(1, 4));

    // 10 ms in queue before the first tick admits + emits token 1
    clock.advance(10);
    assert!(core.tick().is_empty());
    core.assert_invariants();
    // 3 ms per decode tick; budget 4 finishes on the third step
    let mut done = Vec::new();
    for _ in 0..3 {
        clock.advance(3);
        done.extend(core.tick());
        core.assert_invariants();
    }
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, id);
    assert_eq!(done[0].reason, FinishReason::Done);
    assert_eq!(done[0].tokens, vec![1, 1, 1, 1]);

    let h = core.hists;
    assert_eq!(h.queue_wait_us.count, 1);
    assert_eq!(h.queue_wait_us.percentile(0.50), 11_585, "10 ms -> [8192,16384) geomean");
    // TTFT = queue wait (virtual, 10 ms) + prefill (wall, ~0): same bucket
    assert_eq!(h.ttft_us.count, 1);
    assert_eq!(h.ttft_us.percentile(0.50), 11_585);
    // three decode steps, 3 ms apart, all in one bucket: p50 == p99
    assert_eq!(h.itl_us.count, 3);
    assert_eq!(h.itl_us.percentile(0.50), 2_896, "3 ms -> [2048,4096) geomean");
    assert_eq!(h.itl_us.percentile(0.99), 2_896);

    // the span records the same lifecycle end to end
    let spans = core.take_spans();
    assert_eq!(spans.len(), 1);
    let s = spans[0];
    assert_eq!(s.id, id);
    assert_eq!(s.queue_wait_us, 10_000);
    assert_eq!(s.admitted_at_us, 10_000);
    assert_eq!(s.decoded, 4);
    assert_eq!(s.decode_us, 9_000, "admission at 10 ms, finish at 19 ms");
    assert_eq!(s.reason, "done");
    assert_eq!((s.prefix_hit_tokens, s.prefix_miss_tokens), (0, 0), "no prefix cache attached");
}

/// Upstream queue time (`queued_for_ms`, stamped by the serving front
/// door before `submit` sees the job) counts into queue wait and TTFT.
#[test]
fn upstream_queue_time_counts_into_ttft() {
    let gen = MockGen::new(1, &[(1, 0)]);
    let cfg = SchedulerConfig { slots: 1, ..Default::default() };
    let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
    let mut j = job(1, 1);
    j.queued_for_ms = 10;
    core.submit(j);
    let done = drain(&mut core);
    assert_eq!(done.len(), 1);
    assert_eq!(core.hists.queue_wait_us.percentile(0.50), 11_585, "10 ms upstream wait");
    let spans = core.take_spans();
    assert_eq!(spans[0].queue_wait_us, 10_000);
}

/// Both rings are bounded: a burst far beyond `trace_capacity` keeps
/// memory fixed, counts every overwritten entry, and retains the
/// *newest* records.
#[test]
fn trace_rings_are_bounded_and_keep_newest() {
    let script: Vec<(u32, usize)> = (1..=12u32).map(|k| (k, 1)).collect();
    let gen = MockGen::new(2, &script);
    let cfg =
        SchedulerConfig { slots: 2, trace: true, trace_capacity: 4, ..Default::default() };
    let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
    let ids: Vec<u64> = (1..=12u32).map(|k| core.submit(job(k, 8))).collect();
    let done = drain(&mut core);
    assert_eq!(done.len(), 12, "drops affect the trace, never the replies");

    assert!(core.trace().len() <= 4, "event ring respects its capacity");
    let spans = core.spans().to_vec();
    assert!(spans.len() <= 4, "span ring respects its capacity");
    // 12 spans were pushed into capacity 4: 8 dropped, plus the event
    // ring's own drops (2 events per request = 24 pushed, 20 dropped)
    assert_eq!(core.trace_dropped(), 8 + 20);
    assert_eq!(core.stats.trace_dropped, 28, "surfaced through SchedStats too");
    let last = spans.last().expect("span ring holds the newest records");
    assert_eq!(last.id, *ids.last().expect("twelve ids"), "newest span survives the overwrites");
    // take_trace keeps working for the sim tests, and drains
    assert!(!core.take_trace().is_empty());
    assert!(core.trace().is_empty());
}

fn tiny() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 192,
        vocab: 96,
        seq_len: 32,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

/// Drive the continuous scheduler over a real `NativeEngine` under the
/// given observability config and give back each request's stream in
/// submission order.
fn run_with_obs(
    weights: &Weights,
    slots: usize,
    trace: bool,
    profile_every: u64,
    prompts: &[Vec<u32>],
    params: &[DecodeParams],
) -> Vec<Vec<u32>> {
    let window = 16usize;
    let engine =
        NativeEngine::new(weights.clone(), &BTreeMap::new(), window, 42).with_slots(slots);
    let cfg = SchedulerConfig { slots, trace, profile_every, ..Default::default() };
    let mut core = Scheduler::new(engine, ManualClock::default(), cfg);
    let ids: Vec<u64> = prompts
        .iter()
        .zip(params)
        .map(|(p, d)| {
            core.submit(Job { prompt: p.clone(), params: *d, timeout_ms: None, queued_for_ms: 0 })
        })
        .collect();
    let done = drain(&mut core);
    assert_eq!(done.len(), ids.len());
    let by_id: BTreeMap<u64, Vec<u32>> = done
        .into_iter()
        .map(|c| {
            assert_eq!(c.reason, FinishReason::Done);
            (c.id, c.tokens)
        })
        .collect();
    ids.iter().map(|id| by_id[id].clone()).collect()
}

/// Acceptance: observability is isolation-safe.  With tracing on and
/// *every* tick profiled, the scheduler's decoded streams are
/// bit-identical to an untraced run — fused multi-slot decode included
/// — and both match the static `Generator` reference on the same
/// weights.  The timers only ever read the clock; they never touch the
/// math.
#[test]
fn tracing_and_profiling_never_change_decoded_streams() {
    let cfg = tiny();
    let weights = Weights::synthetic(&cfg, 17);
    let prompts = vec![vec![5u32, 10, 15], vec![7u32], vec![5u32, 10, 15], vec![9u32, 4]];
    let params = vec![
        DecodeParams::greedy(5),
        DecodeParams::greedy(3),
        DecodeParams::greedy(4),
        DecodeParams::greedy(6),
    ];

    // static reference: the Generator path on the same engine kind
    let mut static_engine = NativeEngine::new(weights.clone(), &BTreeMap::new(), 16, 42);
    let reference = static_engine.generate(&prompts, &params).unwrap().outputs;

    // 3 slots exercises the fused multi-slot step; profile_every: 1
    // stamps every tick and every engine-side fused call
    let traced = run_with_obs(&weights, 3, true, 1, &prompts, &params);
    let untraced = run_with_obs(&weights, 3, false, 0, &prompts, &params);
    assert_eq!(traced, untraced, "tracing/profiling changed a decoded stream");
    assert_eq!(traced, reference, "scheduler diverged from the static reference");

    // single slot (sequential decode) under full profiling too
    let single = run_with_obs(&weights, 1, true, 1, &prompts, &params);
    assert_eq!(single, reference, "single-slot profiled run diverged");
}

/// Every sample family in the Prometheus text has exactly one `# TYPE`
/// line, and every sample line belongs to a declared family.
fn check_prometheus(prom: &str) -> BTreeSet<String> {
    let mut families = BTreeSet::new();
    for l in prom.lines() {
        if let Some(rest) = l.strip_prefix("# TYPE ") {
            let name = rest.split(' ').next().expect("family name").to_string();
            assert!(families.insert(name.clone()), "duplicate # TYPE for {name}");
        }
    }
    for l in prom.lines() {
        if l.is_empty() || l.starts_with('#') {
            continue;
        }
        let metric = l.split(|c: char| c == ' ' || c == '{').next().expect("metric name");
        let base = metric
            .strip_suffix("_sum")
            .or_else(|| metric.strip_suffix("_count"))
            .unwrap_or(metric);
        assert!(
            families.contains(base) || families.contains(metric),
            "sample {metric} has no # TYPE family"
        );
    }
    families
}

/// The whole stats surface over TCP: a stats line parses as JSON,
/// carries the first-class gauges and phase histograms, embeds a valid
/// Prometheus rendering, and its counters are monotone across calls.
#[test]
fn stats_round_trip_over_tcp() {
    use db_llm::coordinator::metrics::Metrics;

    let cfg = tiny();
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let factory_cfg = cfg.clone();
    let addr = serve_continuous(
        move || {
            let weights = Weights::synthetic(&factory_cfg, 31);
            Ok(NativeEngine::new(weights, &BTreeMap::new(), factory_cfg.seq_len, 5)
                .with_slots(2))
        },
        "127.0.0.1:0",
        64,
        SchedulerConfig { slots: 2, trace: true, profile_every: 1, ..Default::default() },
        1,
        metrics.clone(),
        running.clone(),
    )
    .unwrap();

    let mut stream = loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    };
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();

    let mut ask = |stream: &mut std::net::TcpStream, req: &str| -> Json {
        writeln!(stream, "{req}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };

    // decode something so the phase histograms have mass
    let gen = ask(&mut stream, "{\"prompt\": [5, 10, 15], \"max_tokens\": 6}");
    assert_eq!(gen.usize_list("tokens").unwrap().len(), 6);

    let first = ask(&mut stream, "{\"cmd\": \"stats\"}");
    let stats = first.get("stats").unwrap();
    let counters = stats.get("counters").unwrap();
    let req1 = counters.get("requests").unwrap().as_usize().unwrap();
    let resp1 = counters.get("responses").unwrap().as_usize().unwrap();
    assert!(req1 >= 1 && resp1 >= 1, "generate traffic must be counted");
    // first-class gauges, not derived strings
    let gauges = stats.get("gauges").unwrap();
    for g in ["prefix_hit_rate", "mean_decode_batch", "slot_occ", "queue_depth"] {
        gauges.get(g).unwrap().as_f64().unwrap();
    }
    // phase histograms with mass from the decode above
    let hists = stats.get("histograms").unwrap();
    let ttft = hists.get("ttft_us").unwrap();
    assert!(ttft.get("count").unwrap().as_usize().unwrap() >= 1);
    assert!(ttft.get("p50_us").unwrap().as_usize().unwrap() >= 1);
    let itl = hists.get("itl_us").unwrap();
    assert!(itl.get("count").unwrap().as_usize().unwrap() >= 5, "6 tokens -> 5 steps");
    // per-tick profiling totals flushed through the stats surface
    let profile = stats.get("profile").unwrap();
    assert!(profile.get("profiled_ticks").unwrap().as_usize().unwrap() >= 1);
    assert!(profile.get("engine_prefill_calls").unwrap().as_usize().unwrap() >= 1);

    // the embedded Prometheus text is well-formed
    let prom = first.get("prometheus").unwrap().as_str().unwrap().to_string();
    let families = check_prometheus(&prom);
    for f in [
        "dbllm_requests_total",
        "dbllm_ttft_us",
        "dbllm_itl_us",
        "dbllm_queue_wait_us",
        "dbllm_prefill_us",
        "dbllm_tick_us",
        "dbllm_prefix_hit_rate",
        "dbllm_slot_occ",
        "dbllm_mean_decode_batch",
    ] {
        assert!(families.contains(f), "missing family {f} in:\n{prom}");
    }

    // counters are monotone across a second round of traffic
    let gen2 = ask(&mut stream, "{\"prompt\": [5, 10, 15], \"max_tokens\": 6}");
    assert_eq!(gen2.usize_list("tokens").unwrap().len(), 6);
    let second = ask(&mut stream, "{\"cmd\": \"stats\"}");
    let c2 = second.get("stats").unwrap().get("counters").unwrap();
    let req2 = c2.get("requests").unwrap().as_usize().unwrap();
    let resp2 = c2.get("responses").unwrap().as_usize().unwrap();
    assert!(req2 > req1, "requests counter must be monotone ({req1} -> {req2})");
    assert!(resp2 > resp1, "responses counter must be monotone ({resp1} -> {resp2})");

    // unknown commands error without dropping the connection
    let bad = ask(&mut stream, "{\"cmd\": \"reboot\"}");
    assert!(bad.get("error").unwrap().as_str().unwrap().contains("unknown cmd"));
    let gen3 = ask(&mut stream, "{\"prompt\": [1], \"max_tokens\": 2}");
    assert_eq!(gen3.usize_list("tokens").unwrap().len(), 2);

    running.store(false, std::sync::atomic::Ordering::Relaxed);
}
