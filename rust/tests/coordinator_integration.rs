//! Integration: the coordinator over real artifacts — DAD fine-tuning
//! (XLA gradients + rust AdamW), the serving stack end to end over TCP,
//! and generation determinism.  Requires `make artifacts`.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use db_llm::coordinator::batcher::BatchPolicy;
use db_llm::coordinator::finetune::{DadConfig, DadTrainer};
use db_llm::coordinator::metrics::Metrics;
use db_llm::coordinator::serve::{serve, Engine};
use db_llm::data::TokenStream;
use db_llm::quant::{fdb::Fdb, Calib, Quantizer};
use db_llm::runtime::{session::load_teacher, Runtime, Session};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[test]
fn dad_training_reduces_distill_loss() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::open(artifacts_dir()).unwrap();
    let weights = load_teacher(&rt, "S").unwrap();
    let empty = Calib::empty(0);
    let mut fdb_layers = BTreeMap::new();
    let _ = weights.map_linears(|name, w| {
        let q = Fdb { group: 64 }.quantize(w, &empty);
        let fdb = q.fdb.unwrap();
        fdb_layers.insert(name.to_string(), fdb);
        q.w_hat
    });
    let teacher_session = Session::new(&rt, &weights).unwrap();
    let calib = TokenStream::load(artifacts_dir().join("calib_S.tok")).unwrap();
    let cfg = DadConfig { lr: 3e-4, epochs: 2, max_batches: 16, ..Default::default() };
    let mut trainer = DadTrainer::new(&rt, "S", &fdb_layers, cfg).unwrap();
    trainer
        .train(&mut rt, &teacher_session, &weights, &fdb_layers, &calib, |_| {})
        .unwrap();
    // two epochs over the SAME 16 batches: epoch means are comparable
    let n = trainer.history.len();
    assert_eq!(n, 32, "expected 2 epochs x 16 batches");
    let e1: f64 = trainer.history[..16].iter().map(|r| r.total).sum::<f64>() / 16.0;
    let e2: f64 = trainer.history[16..].iter().map(|r| r.total).sum::<f64>() / 16.0;
    assert!(e2 < e1, "DAD distill loss did not decrease: epoch1 {e1} -> epoch2 {e2}");
    // applying the scales back keeps every layer on its (moved) grid
    let mut layers = fdb_layers.clone();
    trainer.apply(&mut layers, &weights);
    for (name, l) in &layers {
        assert!(l.sparsity() > 0.3, "{name} sparsity collapsed");
    }
}

#[test]
fn dad_gamma_sweep_is_finite_everywhere() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::open(artifacts_dir()).unwrap();
    let weights = load_teacher(&rt, "S").unwrap();
    let empty = Calib::empty(0);
    let mut fdb_layers = BTreeMap::new();
    let _ = weights.map_linears(|name, w| {
        let q = Fdb { group: 64 }.quantize(w, &empty);
        fdb_layers.insert(name.to_string(), q.fdb.unwrap());
        q.w_hat
    });
    let teacher_session = Session::new(&rt, &weights).unwrap();
    let calib = TokenStream::load(artifacts_dir().join("calib_S.tok")).unwrap();
    for gamma in [0.0, 0.5, 1.0] {
        let cfg = DadConfig { gamma, max_batches: 2, ..Default::default() };
        let mut trainer = DadTrainer::new(&rt, "S", &fdb_layers, cfg).unwrap();
        trainer
            .train(&mut rt, &teacher_session, &weights, &fdb_layers, &calib, |_| {})
            .unwrap();
        for rec in &trainer.history {
            assert!(rec.total.is_finite() && rec.dad.is_finite(), "gamma {gamma}");
        }
    }
}

#[test]
fn tcp_serving_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let addr = serve(
        || {
            let rt = Runtime::open(artifacts_dir())?;
            let weights = load_teacher(&rt, "S")?;
            let vocab = rt.manifest.vocab();
            let session = Session::new(&rt, &weights)?;
            Ok((rt, Engine::new(session, vocab, 1)))
        },
        "127.0.0.1:0",
        BatchPolicy::default(),
        metrics.clone(),
        running.clone(),
    )
    .unwrap();

    let mut stream = loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(50)),
        }
    };
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // greedy generation is deterministic: same prompt -> same tokens
    let mut responses = Vec::new();
    for _ in 0..2 {
        writeln!(stream, "{{\"prompt\": [5, 10, 15], \"max_tokens\": 6}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = db_llm::util::Json::parse(line.trim()).unwrap();
        let toks = j.usize_list("tokens").unwrap();
        assert_eq!(toks.len(), 6);
        responses.push(toks);
    }
    assert_eq!(responses[0], responses[1], "greedy decode must be deterministic");

    // malformed requests produce an error line, not a crash
    writeln!(stream, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "got {line}");

    // still serving after the bad request
    writeln!(stream, "{{\"prompt\": [1], \"max_tokens\": 2}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("tokens"));

    running.store(false, std::sync::atomic::Ordering::Relaxed);
    assert!(metrics.responses.load(std::sync::atomic::Ordering::Relaxed) >= 3);
}
