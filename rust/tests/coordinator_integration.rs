//! Integration: the coordinator over real artifacts — DAD fine-tuning
//! (XLA gradients + rust AdamW), the serving stack end to end over TCP,
//! and generation determinism.  The XLA-backed tests require
//! `make artifacts`; the worker-pool tests drive `worker_loop` with a
//! fake generator and run everywhere.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::AtomicBool;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use db_llm::coordinator::batcher::BatchPolicy;
use db_llm::coordinator::finetune::{DadConfig, DadTrainer};
use db_llm::coordinator::metrics::Metrics;
use db_llm::coordinator::serve::{
    serve, worker_loop, DecodeParams, Engine, EngineWorker, Generation, Generator, Request,
};
use db_llm::data::TokenStream;
use db_llm::quant::{fdb::Fdb, Calib, Quantizer};
use db_llm::runtime::{session::load_teacher, Runtime, Session};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[test]
fn dad_training_reduces_distill_loss() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::open(artifacts_dir()).unwrap();
    let weights = load_teacher(&rt, "S").unwrap();
    let empty = Calib::empty(0);
    let mut fdb_layers = BTreeMap::new();
    let _ = weights.map_linears(|name, w| {
        let q = Fdb { group: 64 }.quantize(w, &empty);
        let fdb = q.fdb.unwrap();
        fdb_layers.insert(name.to_string(), fdb);
        q.w_hat
    });
    let teacher_session = Session::new(&rt, &weights).unwrap();
    let calib = TokenStream::load(artifacts_dir().join("calib_S.tok")).unwrap();
    let cfg = DadConfig { lr: 3e-4, epochs: 2, max_batches: 16, ..Default::default() };
    let mut trainer = DadTrainer::new(&rt, "S", &fdb_layers, cfg).unwrap();
    trainer
        .train(&mut rt, &teacher_session, &weights, &fdb_layers, &calib, |_| {})
        .unwrap();
    // two epochs over the SAME 16 batches: epoch means are comparable
    let n = trainer.history.len();
    assert_eq!(n, 32, "expected 2 epochs x 16 batches");
    let e1: f64 = trainer.history[..16].iter().map(|r| r.total).sum::<f64>() / 16.0;
    let e2: f64 = trainer.history[16..].iter().map(|r| r.total).sum::<f64>() / 16.0;
    assert!(e2 < e1, "DAD distill loss did not decrease: epoch1 {e1} -> epoch2 {e2}");
    // applying the scales back keeps every layer on its (moved) grid
    let mut layers = fdb_layers.clone();
    trainer.apply(&mut layers, &weights);
    for (name, l) in &layers {
        assert!(l.sparsity() > 0.3, "{name} sparsity collapsed");
    }
}

#[test]
fn dad_gamma_sweep_is_finite_everywhere() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::open(artifacts_dir()).unwrap();
    let weights = load_teacher(&rt, "S").unwrap();
    let empty = Calib::empty(0);
    let mut fdb_layers = BTreeMap::new();
    let _ = weights.map_linears(|name, w| {
        let q = Fdb { group: 64 }.quantize(w, &empty);
        fdb_layers.insert(name.to_string(), q.fdb.unwrap());
        q.w_hat
    });
    let teacher_session = Session::new(&rt, &weights).unwrap();
    let calib = TokenStream::load(artifacts_dir().join("calib_S.tok")).unwrap();
    for gamma in [0.0, 0.5, 1.0] {
        let cfg = DadConfig { gamma, max_batches: 2, ..Default::default() };
        let mut trainer = DadTrainer::new(&rt, "S", &fdb_layers, cfg).unwrap();
        trainer
            .train(&mut rt, &teacher_session, &weights, &fdb_layers, &calib, |_| {})
            .unwrap();
        for rec in &trainer.history {
            assert!(rec.total.is_finite() && rec.dad.is_finite(), "gamma {gamma}");
        }
    }
}

#[test]
fn tcp_serving_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let addr = serve(
        || {
            let rt = Runtime::open(artifacts_dir())?;
            let weights = load_teacher(&rt, "S")?;
            let vocab = rt.manifest.vocab();
            let session = Session::new(&rt, &weights)?;
            Ok(EngineWorker { rt, engine: Engine::new(session, vocab, 1) })
        },
        "127.0.0.1:0",
        BatchPolicy::default(),
        1,
        metrics.clone(),
        running.clone(),
    )
    .unwrap();

    let mut stream = loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(50)),
        }
    };
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // greedy generation is deterministic: same prompt -> same tokens
    let mut responses = Vec::new();
    for _ in 0..2 {
        writeln!(stream, "{{\"prompt\": [5, 10, 15], \"max_tokens\": 6}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = db_llm::util::Json::parse(line.trim()).unwrap();
        let toks = j.usize_list("tokens").unwrap();
        assert_eq!(toks.len(), 6);
        responses.push(toks);
    }
    assert_eq!(responses[0], responses[1], "greedy decode must be deterministic");

    // malformed requests produce an error line, not a crash
    writeln!(stream, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "got {line}");

    // still serving after the bad request
    writeln!(stream, "{{\"prompt\": [1], \"max_tokens\": 2}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("tokens"));

    running.store(false, std::sync::atomic::Ordering::Relaxed);
    assert!(metrics.responses.load(std::sync::atomic::Ordering::Relaxed) >= 3);
}

/// Mixed per-request decode state over real artifacts: one server with
/// two workers, concurrent clients with different temperatures and
/// budgets — every request answered exactly once, at exactly its own
/// length, and greedy rows stay deterministic even when batched next to
/// sampled rows.
#[test]
fn tcp_mixed_batch_multi_worker() {
    if !have_artifacts() {
        return;
    }
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let addr = serve(
        || {
            let rt = Runtime::open(artifacts_dir())?;
            let weights = load_teacher(&rt, "S")?;
            let vocab = rt.manifest.vocab();
            let session = Session::new(&rt, &weights)?;
            Ok(EngineWorker { rt, engine: Engine::new(session, vocab, 1) })
        },
        "127.0.0.1:0",
        BatchPolicy::default(),
        2,
        metrics.clone(),
        running.clone(),
    )
    .unwrap();

    let mut handles = Vec::new();
    for c in 0..4usize {
        handles.push(std::thread::spawn(move || {
            let mut stream = loop {
                match std::net::TcpStream::connect(addr) {
                    Ok(s) => break s,
                    Err(_) => std::thread::sleep(Duration::from_millis(50)),
                }
            };
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            // even clients: greedy, short; odd clients: sampled, long
            let (max_tokens, temperature) = if c % 2 == 0 { (3, 0.0) } else { (7, 1.3) };
            let mut outs = Vec::new();
            for _ in 0..3 {
                writeln!(
                    stream,
                    "{{\"prompt\": [5, 10, 15], \"max_tokens\": {max_tokens}, \
                     \"temperature\": {temperature}}}"
                )
                .unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let j = db_llm::util::Json::parse(line.trim()).unwrap();
                let toks = j.usize_list("tokens").unwrap();
                assert_eq!(toks.len(), max_tokens, "row must honor its own budget");
                outs.push(toks);
            }
            (c, outs)
        }));
    }
    let mut greedy_rows: Vec<Vec<usize>> = Vec::new();
    let mut answered = 0usize;
    for h in handles {
        let (c, outs) = h.join().unwrap();
        answered += outs.len();
        if c % 2 == 0 {
            greedy_rows.extend(outs);
        }
    }
    assert_eq!(answered, 12, "every request answered exactly once");
    for row in &greedy_rows[1..] {
        assert_eq!(row, &greedy_rows[0], "greedy rows deterministic in mixed batches");
    }
    running.store(false, std::sync::atomic::Ordering::Relaxed);
    assert_eq!(metrics.responses.load(std::sync::atomic::Ordering::Relaxed), 12);
    assert_eq!(metrics.errors.load(std::sync::atomic::Ordering::Relaxed), 0);
}

/// Test double: echoes `prompt[0]` for exactly `max_tokens` steps.
struct EchoGen;

impl Generator for EchoGen {
    fn generate(
        &mut self,
        prompts: &[Vec<u32>],
        params: &[DecodeParams],
    ) -> anyhow::Result<Generation> {
        let outputs = prompts
            .iter()
            .zip(params)
            .map(|(p, d)| vec![p[0]; d.max_tokens])
            .collect::<Vec<_>>();
        let steps = params.iter().map(|d| d.max_tokens).max().unwrap_or(0);
        Ok(Generation { outputs, steps })
    }
}

/// Test double: every batch fails.
struct FailGen;

impl Generator for FailGen {
    fn generate(
        &mut self,
        _prompts: &[Vec<u32>],
        _params: &[DecodeParams],
    ) -> anyhow::Result<Generation> {
        anyhow::bail!("injected engine failure")
    }
}

fn pool_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, linger: Duration::from_millis(2), ..Default::default() }
}

/// A worker error must degrade to one error reply per request — never a
/// dropped batch (the seed bug left clients on a closed channel).
#[test]
fn worker_error_replies_per_request() {
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let (tx, rx) = channel::<Request>();
    let rx = Arc::new(Mutex::new(rx));
    let worker = {
        let (rx, m, r) = (rx.clone(), metrics.clone(), running.clone());
        std::thread::spawn(move || worker_loop(FailGen, rx, pool_policy(), m, r))
    };

    let mut replies = Vec::new();
    for i in 0..3 {
        let (reply_tx, reply_rx) = channel();
        metrics.queue_depth.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        tx.send(Request {
            prompt: vec![i],
            params: DecodeParams::greedy(4),
            reply: reply_tx,
            arrived: Instant::now(),
            timeout_ms: None,
        })
        .unwrap();
        replies.push(reply_rx);
    }
    for reply_rx in replies {
        let resp = reply_rx.recv().expect("reply channel must not be dropped");
        let msg = resp.error.expect("error reply expected");
        assert!(msg.contains("injected engine failure"), "{msg}");
        assert!(resp.tokens.is_empty());
    }
    assert_eq!(metrics.errors.load(std::sync::atomic::Ordering::Relaxed), 3);
    drop(tx);
    worker.join().unwrap();
}

/// Requests still queued when `running` is cleared get an error reply
/// (never a silently dropped reply channel), and the worker exits
/// without waiting for the request senders to disconnect.
#[test]
fn shutdown_answers_queued_requests() {
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(false));
    let (tx, rx) = channel::<Request>();
    let rx = Arc::new(Mutex::new(rx));
    let mut replies = Vec::new();
    for i in 0..3 {
        let (reply_tx, reply_rx) = channel();
        metrics.queue_depth.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        tx.send(Request {
            prompt: vec![i],
            params: DecodeParams::greedy(4),
            reply: reply_tx,
            arrived: Instant::now(),
            timeout_ms: None,
        })
        .unwrap();
        replies.push(reply_rx);
    }
    let worker = {
        let (rx, m, r) = (rx.clone(), metrics.clone(), running.clone());
        std::thread::spawn(move || worker_loop(EchoGen, rx, pool_policy(), m, r))
    };
    for reply_rx in replies {
        let resp = reply_rx.recv().expect("queued request must still be answered");
        let msg = resp.error.expect("error reply expected");
        assert!(msg.contains("shutting down"), "{msg}");
    }
    // the sender is still alive: the worker exits on the flag alone
    worker.join().unwrap();
    assert_eq!(metrics.queue_depth.load(std::sync::atomic::Ordering::Relaxed), 0);
    drop(tx);
}

/// The static-batch stall is *measured*, not hidden: a row that
/// finished early counts only its actual decoded tokens, and the steps
/// it sat idle inside the still-running batch land in
/// `stalled_row_steps` (the waste the continuous scheduler removes).
#[test]
fn static_batch_stall_accounted() {
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let (tx, rx) = channel::<Request>();
    let rx = Arc::new(Mutex::new(rx));
    // queue one batch worth of mixed budgets BEFORE the worker starts,
    // so exactly one batch [1, 2, 4] is collected
    let mut replies = Vec::new();
    for budget in [1usize, 2, 4] {
        let (reply_tx, reply_rx) = channel();
        metrics.queue_depth.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        tx.send(Request {
            prompt: vec![9],
            params: DecodeParams::greedy(budget),
            reply: reply_tx,
            arrived: Instant::now(),
            timeout_ms: None,
        })
        .unwrap();
        replies.push((budget, reply_rx));
    }
    let worker = {
        let (rx, m, r) = (rx.clone(), metrics.clone(), running.clone());
        std::thread::spawn(move || worker_loop(EchoGen, rx, pool_policy(), m, r))
    };
    for (budget, reply_rx) in replies {
        let resp = reply_rx.recv().unwrap();
        assert_eq!(resp.tokens.len(), budget, "actual decoded tokens reported");
    }
    drop(tx);
    worker.join().unwrap();
    let ord = std::sync::atomic::Ordering::Relaxed;
    // EchoGen ran the batch for max(budget)=4 steps: the budget-1 row
    // idled 3 of them, the budget-2 row idled 2
    assert_eq!(metrics.stalled_row_steps.load(ord), 5, "{}", metrics.snapshot());
    assert_eq!(metrics.tokens_out.load(ord), 7);
}

/// Several workers competing on one shared queue: every request is
/// answered exactly once with its own budget, and the early-exit /
/// queue-depth accounting converges.
#[test]
fn worker_pool_exactly_once() {
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let (tx, rx) = channel::<Request>();
    let rx = Arc::new(Mutex::new(rx));
    let mut workers = Vec::new();
    for _ in 0..3 {
        let (rx, m, r) = (rx.clone(), metrics.clone(), running.clone());
        workers.push(std::thread::spawn(move || worker_loop(EchoGen, rx, pool_policy(), m, r)));
    }

    let n = 48u32;
    let mut replies = Vec::new();
    for i in 0..n {
        let (reply_tx, reply_rx) = channel();
        metrics.queue_depth.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        tx.send(Request {
            prompt: vec![i],
            params: DecodeParams::greedy(1 + (i as usize) % 5),
            reply: reply_tx,
            arrived: Instant::now(),
            timeout_ms: None,
        })
        .unwrap();
        replies.push((i, reply_rx));
    }
    for (i, reply_rx) in replies {
        let resp = reply_rx.recv().expect("exactly one reply per request");
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, vec![i; 1 + (i as usize) % 5], "row echoes its own budget");
        assert!(
            reply_rx.try_recv().is_err(),
            "request {i} must not be answered twice"
        );
    }
    drop(tx);
    for w in workers {
        w.join().unwrap();
    }
    let ord = std::sync::atomic::Ordering::Relaxed;
    assert_eq!(metrics.responses.load(ord), n as u64);
    assert_eq!(metrics.queue_depth.load(ord), 0, "gauge drains back to zero");
    assert!(metrics.batches.load(ord) >= (n as u64).div_ceil(4));
}
