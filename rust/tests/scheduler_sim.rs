//! Deterministic simulation of the continuous-batching scheduler: a
//! scripted [`SlotEngine`] (arrival times, per-request lengths, EOS
//! positions) plus a virtual clock drive the core tick by tick, so the
//! tests assert *exact* slot-assignment traces, refill-before-idle
//! invariants, exactly-one-reply delivery, deadline semantics, and
//! token-for-token equivalence with the static `decode_batch` path.
//! Everything here is artifact-free and runs in every environment.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use db_llm::coordinator::scheduler::{
    serve_continuous, Clock, Completion, FinishReason, Job, ManualClock, Scheduler,
    SchedulerConfig, SlotEngine, TraceEvent,
};
use db_llm::coordinator::serve::{decode_batch, DecodeParams, Generator};
use db_llm::infer::NativeEngine;
use db_llm::model::native::Forward;
use db_llm::model::{ModelConfig, Weights};
use db_llm::util::{Json, Pcg32};

const EOS: u32 = 63;
const VOCAB: usize = 64;

/// Scripted engine: a request is identified by `prompt[0]` (its key)
/// and emits its key for the scripted number of content tokens, then
/// EOS.  Records every prefill/reset so tests can assert which slots
/// ran which requests — and that queued-expired requests never touched
/// a slot.
struct MockGen {
    slots: usize,
    /// key -> content tokens before EOS
    script: BTreeMap<u32, usize>,
    /// per-slot (key, tokens the scheduler has sampled so far)
    state: Vec<Option<(u32, usize)>>,
    prefill_log: Vec<(usize, u32)>,
    /// keys whose prefill fails (engine-failure injection)
    fail_keys: Vec<u32>,
}

impl MockGen {
    fn new(slots: usize, script: &[(u32, usize)]) -> MockGen {
        MockGen {
            slots,
            script: script.iter().copied().collect(),
            state: (0..slots).map(|_| None).collect(),
            prefill_log: Vec::new(),
            fail_keys: Vec::new(),
        }
    }

    fn logits(&self, key: u32, emitted: usize) -> Vec<f32> {
        let n = self.script[&key];
        let mut l = vec![0.0f32; VOCAB];
        let target = if emitted >= n { EOS } else { key };
        l[target as usize] = 10.0;
        l
    }
}

impl SlotEngine for MockGen {
    fn slots(&self) -> usize {
        self.slots
    }

    fn prefill_slot(&mut self, slot: usize, prompt: &[u32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let key = prompt[0];
        anyhow::ensure!(!self.fail_keys.contains(&key), "injected prefill failure for {key}");
        self.prefill_log.push((slot, key));
        self.state[slot] = Some((key, 0));
        Ok(self.logits(key, 0))
    }

    fn step_slot(&mut self, slot: usize, _token: u32) -> anyhow::Result<Vec<f32>> {
        let (key, emitted) = self.state[slot].expect("step on a slot without prefill");
        self.state[slot] = Some((key, emitted + 1));
        Ok(self.logits(key, emitted + 1))
    }

    fn step_slots_atomic(&self) -> bool {
        // step_slot is infallible, so the default batched loop never
        // fails mid-batch: let the scheduler drive the batched path
        true
    }

    fn reset_slot(&mut self, slot: usize) {
        self.state[slot] = None;
    }
}

/// Flake-detector hook: when `DBLLM_TRANSCRIPT_DUMP` names a file,
/// append every seeded completion line to it.  CI runs the suite twice
/// single-threaded and byte-diffs the two dumps, so any nondeterminism
/// in the seeded simulations surfaces as a diff even when both runs
/// pass.
fn dump_transcript(tag: &str, lines: impl IntoIterator<Item = String>) {
    let Ok(path) = std::env::var("DBLLM_TRANSCRIPT_DUMP") else { return };
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .expect("transcript dump file must be writable");
    for l in lines {
        writeln!(f, "{tag}: {l}").expect("transcript dump write");
    }
}

fn greedy_stop(max_tokens: usize) -> DecodeParams {
    DecodeParams { stop: Some(EOS), ..DecodeParams::greedy(max_tokens) }
}

fn job(key: u32, max_tokens: usize, timeout_ms: Option<u64>) -> Job {
    Job { prompt: vec![key], params: greedy_stop(max_tokens), timeout_ms, queued_for_ms: 0 }
}

/// The stream a scripted request must produce: its key for
/// `min(script, budget)` tokens, then EOS iff the budget allows it.
fn expected_stream(key: u32, script: usize, max_tokens: usize) -> Vec<u32> {
    if max_tokens <= script {
        vec![key; max_tokens]
    } else {
        let mut v = vec![key; script];
        v.push(EOS);
        v
    }
}

fn drain<E: SlotEngine, C: Clock>(core: &mut Scheduler<E, C>) -> Vec<Completion> {
    let mut out = Vec::new();
    let mut guard = 0;
    while !core.is_idle() {
        out.extend(core.tick());
        core.assert_invariants();
        guard += 1;
        assert!(guard < 100_000, "scheduler failed to drain");
    }
    out
}

/// Acceptance: a finished slot is refilled *mid-flight* — between two
/// decode steps, while the long-running neighbour slot keeps decoding
/// without a reset — and the exact slot-assignment trace comes out as
/// scripted.
#[test]
fn refill_trace_is_exact() {
    // A: 1 content token (stream len 2), B: 4 (len 5), C: 2 (len 3)
    let gen = MockGen::new(2, &[(1, 1), (2, 4), (3, 2)]);
    let cfg = SchedulerConfig { slots: 2, trace: true, ..Default::default() };
    let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
    let a = core.submit(job(1, 16, None));
    let b = core.submit(job(2, 16, None));
    let c = core.submit(job(3, 16, None));

    let done = drain(&mut core);

    // exactly one completion per request, in finish order: A, then C
    // (slot 0) and B (slot 1) on the same tick
    assert_eq!(done.iter().map(|d| d.id).collect::<Vec<_>>(), vec![a, c, b]);
    assert_eq!(done[0].tokens, vec![1, EOS]);
    assert_eq!(done[1].tokens, vec![3, 3, EOS]);
    assert_eq!(done[2].tokens, vec![2, 2, 2, 2, EOS]);
    assert!(done.iter().all(|d| d.reason == FinishReason::Done));

    // the exact decision sequence: C is admitted into slot 0 as a
    // refill while B is still mid-flight in slot 1 (Admit C precedes
    // Finish B), and B's finish shows an uninterrupted 5-token decode
    let trace = core.take_trace();
    assert_eq!(
        trace,
        vec![
            TraceEvent::Admit { id: a, slot: 0, at_ms: 0, refill: false },
            TraceEvent::Admit { id: b, slot: 1, at_ms: 0, refill: false },
            TraceEvent::Finish { id: a, slot: 0, at_ms: 0, reason: "done", decoded: 2 },
            TraceEvent::Admit { id: c, slot: 0, at_ms: 0, refill: true },
            TraceEvent::Finish { id: c, slot: 0, at_ms: 0, reason: "done", decoded: 3 },
            TraceEvent::Finish { id: b, slot: 1, at_ms: 0, reason: "done", decoded: 5 },
        ]
    );
    // slot 1 was prefilled exactly once: refilling slot 0 never
    // touched the neighbour's sequence
    assert_eq!(core.engine().prefill_log, vec![(0, 1), (1, 2), (0, 3)]);
    assert_eq!(core.stats.refills, 1);
    assert_eq!(core.stats.ticks, 5, "5 lockstep ticks drain 10 tokens on 2 slots");
    assert_eq!(core.stats.busy_slot_ticks, 10);
}

/// Randomized-script soak across seeds: random lengths, budgets, slot
/// counts and submit/tick interleavings.  Invariants: every admitted
/// request gets exactly one completion (no drops, no duplicates), all
/// streams match their closed-form expectation, and — the
/// refill-before-idle invariant — a tick never leaves a slot free
/// while admissible work is queued.
#[test]
fn seeded_random_sims_hold_invariants() {
    for seed in 1..=6u64 {
        let mut rng = Pcg32::seeded(seed);
        let n = 24usize;
        let slots = rng.range(1, 5);
        let mut script = Vec::new();
        let mut jobs = Vec::new();
        for i in 0..n {
            let key = (i + 1) as u32; // unique, < EOS
            let content = rng.range(0, 7);
            let budget = rng.range(1, 9);
            script.push((key, content));
            jobs.push((key, content, budget));
        }
        let gen = MockGen::new(slots, &script);
        let cfg = SchedulerConfig { slots, ..Default::default() };
        let mut core = Scheduler::new(gen, ManualClock::default(), cfg);

        let mut ids = BTreeMap::new();
        let mut completions: Vec<Completion> = Vec::new();
        let mut next = 0usize;
        let mut iters = 0;
        while next < jobs.len() || !core.is_idle() {
            iters += 1;
            assert!(iters < 100_000, "seed {seed}: failed to drain");
            if next < jobs.len() && rng.f32() < 0.5 {
                let (key, content, budget) = jobs[next];
                let id = core.submit(job(key, budget, None));
                ids.insert(id, (key, content, budget));
                next += 1;
                continue;
            }
            let queued_before = core.queue_len();
            let free_before = core.free_slots();
            let before = core.stats.admissions;
            completions.extend(core.tick());
            core.assert_invariants();
            // refill-before-idle: admission must fill min(free, queued)
            // slots — nothing here is expired or zero-budget
            let admitted = (core.stats.admissions - before) as usize;
            assert_eq!(
                admitted,
                queued_before.min(free_before),
                "seed {seed}: a free slot idled while work was queued"
            );
        }
        // exactly one completion per request, each with its exact stream
        assert_eq!(completions.len(), ids.len(), "seed {seed}");
        let mut seen = std::collections::BTreeSet::new();
        for c in &completions {
            assert!(seen.insert(c.id), "seed {seed}: duplicate completion for {}", c.id);
            let (key, content, budget) = ids[&c.id];
            assert_eq!(c.tokens, expected_stream(key, content, budget), "seed {seed}");
            assert_eq!(c.reason, FinishReason::Done, "seed {seed}");
        }
        assert_eq!(core.stats.timeouts, 0, "seed {seed}");
        dump_transcript(
            &format!("sched_sim seed={seed}"),
            completions
                .iter()
                .map(|c| format!("id={} reason={:?} tokens={:?}", c.id, c.reason, c.tokens))
                .chain(std::iter::once(format!(
                    "counters ticks={} admissions={} refills={} busy={}",
                    core.stats.ticks,
                    core.stats.admissions,
                    core.stats.refills,
                    core.stats.busy_slot_ticks,
                ))),
        );
    }
}

/// A request that exceeds its deadline mid-decode is evicted with the
/// tokens decoded so far, flagged timeout.
#[test]
fn deadline_eviction_returns_partial_result() {
    let gen = MockGen::new(1, &[(1, 100)]);
    let clock = ManualClock::default();
    let cfg = SchedulerConfig { slots: 1, trace: true, ..Default::default() };
    let mut core = Scheduler::new(gen, clock.clone(), cfg);
    let id = core.submit(job(1, 50, Some(5)));

    assert!(core.tick().is_empty(), "tick 1: admitted, one token, no deadline yet");
    clock.advance(2);
    assert!(core.tick().is_empty(), "tick 2: still within deadline");
    clock.advance(3); // now == 5 == deadline
    let done = core.tick();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, id);
    assert_eq!(done[0].reason, FinishReason::Timeout);
    assert_eq!(done[0].tokens, vec![1, 1, 1], "three ticks decoded three tokens");
    assert!(core.is_idle(), "the slot is free again");
    assert_eq!(core.stats.timeouts, 1);
    let trace = core.take_trace();
    assert_eq!(
        trace.last(),
        Some(&TraceEvent::Finish { id, slot: 0, at_ms: 5, reason: "timeout", decoded: 3 })
    );
}

/// A zero-timeout request is answered (flagged timeout, zero tokens)
/// before ever occupying a slot, and traffic behind it is unaffected.
#[test]
fn zero_timeout_rejected_before_slot() {
    let gen = MockGen::new(1, &[(1, 2), (2, 1)]);
    let cfg = SchedulerConfig { slots: 1, trace: true, ..Default::default() };
    let mut core = Scheduler::new(gen, ManualClock::default(), cfg);
    let dead = core.submit(job(1, 8, Some(0)));
    let live = core.submit(job(2, 8, None));
    let done = drain(&mut core);
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].id, dead);
    assert_eq!(done[0].reason, FinishReason::Timeout);
    assert!(done[0].tokens.is_empty());
    assert_eq!(done[1].id, live);
    assert_eq!(done[1].tokens, vec![2, EOS]);
    // the expired request never touched the engine
    assert_eq!(core.engine().prefill_log, vec![(0, 2)]);
    assert_eq!(core.trace()[0], TraceEvent::Expire { id: dead, at_ms: 0 });
    assert_eq!(core.stats.admissions, 1);
}

/// A deadline can expire while the request is still waiting for a slot:
/// it is answered without a slot, and the slot-holder is unaffected.
/// (The holder is admitted before the waiter arrives — EDF admission
/// would otherwise hand the only slot to the tighter deadline.)
#[test]
fn queued_request_expires_without_a_slot() {
    let gen = MockGen::new(1, &[(1, 100), (2, 1)]);
    let clock = ManualClock::default();
    let cfg = SchedulerConfig { slots: 1, ..Default::default() };
    let mut core = Scheduler::new(gen, clock.clone(), cfg);
    let holder = core.submit(job(1, 10, None));
    let mut done = Vec::new();
    done.extend(core.tick());
    let waiter = core.submit(job(2, 8, Some(3)));

    for _ in 0..4 {
        done.extend(core.tick());
        clock.advance(1);
    }
    // by the last tick (clock 3 >= deadline 3) the waiter expired
    // in-queue
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, waiter);
    assert_eq!(done[0].reason, FinishReason::Timeout);
    assert!(done[0].tokens.is_empty());
    assert_eq!(core.engine().prefill_log, vec![(0, 1)], "waiter never prefilled");

    let rest = drain(&mut core);
    assert_eq!(rest.len(), 1);
    assert_eq!(rest[0].id, holder);
    assert_eq!(rest[0].tokens.len(), 10, "holder decoded its full budget undisturbed");
}

/// EDF admission: a tight-deadline request that arrives *after* a
/// loose-deadline one jumps the queue when the slot frees up — and the
/// no-deadline request ranks last of all.
#[test]
fn edf_admits_tight_deadline_late_arrival_first() {
    // holder pins the only slot; loose (10s budget), then nodeadline,
    // then tight (50ms budget) queue up behind it in that order
    let gen = MockGen::new(1, &[(1, 2), (2, 1), (3, 1), (4, 1)]);
    let clock = ManualClock::default();
    let cfg = SchedulerConfig { slots: 1, trace: true, ..Default::default() };
    let mut core = Scheduler::new(gen, clock.clone(), cfg);
    let holder = core.submit(job(1, 16, None));
    let mut done = Vec::new();
    done.extend(core.tick()); // holder admitted
    let loose = core.submit(job(2, 16, Some(10_000)));
    let nodeadline = core.submit(job(3, 16, None));
    let tight = core.submit(job(4, 16, Some(50)));

    done.extend(drain(&mut core));
    assert_eq!(done.len(), 4, "every request answered exactly once");
    assert!(done.iter().all(|c| c.reason == FinishReason::Done));

    // admission order: holder (already in), then tight, loose,
    // no-deadline — not arrival order
    let admits: Vec<u64> = core
        .take_trace()
        .into_iter()
        .filter_map(|ev| match ev {
            TraceEvent::Admit { id, .. } => Some(id),
            _ => None,
        })
        .collect();
    assert_eq!(admits, vec![holder, tight, loose, nodeadline]);
    assert_eq!(
        core.engine().prefill_log,
        vec![(0, 1), (0, 4), (0, 2), (0, 3)],
        "EDF must hand the freed slot to the tight deadline first"
    );
}

/// Engine failure on one request degrades to an error completion; the
/// slot is recycled for the next request the same tick.
#[test]
fn prefill_failure_is_per_request() {
    let mut gen = MockGen::new(1, &[(1, 1), (2, 1)]);
    gen.fail_keys.push(1);
    let mut core =
        Scheduler::new(gen, ManualClock::default(), SchedulerConfig::default());
    let bad = core.submit(job(1, 4, None));
    let good = core.submit(job(2, 4, None));
    let done = drain(&mut core);
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].id, bad);
    assert!(matches!(&done[0].reason, FinishReason::Error(m) if m.contains("injected")));
    assert_eq!(done[1].id, good);
    assert_eq!(done[1].tokens, vec![2, EOS]);
}

// ---------------------------------------------------------------------
// Equivalence with the static path (real NativeEngine, real model math)
// ---------------------------------------------------------------------

fn tiny() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 192,
        vocab: 96,
        seq_len: 32,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

/// The full-recompute reference: a `decode_batch` step function that
/// re-runs the batched native forward over every row's whole window —
/// what the XLA decode loop does, minus the device (same helper as
/// `tests/infer_integration.rs`).
fn full_recompute_step(
    weights: &Weights,
    b: usize,
    t: usize,
    vocab: usize,
) -> impl FnMut(&[i32]) -> anyhow::Result<Vec<f32>> + '_ {
    move |toks: &[i32]| {
        let mut out = vec![0.0f32; b * t * vocab];
        for r in 0..b {
            let row: Vec<u32> = toks[r * t..(r + 1) * t].iter().map(|&x| x as u32).collect();
            let logits = Forward::new(weights).run(&row);
            out[r * t * vocab..(r + 1) * t * vocab].copy_from_slice(&logits.data);
        }
        Ok(out)
    }
}

/// Drive the continuous scheduler over a `NativeEngine` and give back
/// each request's stream in submission order.
fn run_scheduled(
    weights: &Weights,
    window: usize,
    slots: usize,
    refill: bool,
    prompts: &[Vec<u32>],
    params: &[DecodeParams],
) -> Vec<Vec<u32>> {
    let engine = NativeEngine::new(weights.clone(), &BTreeMap::new(), window, 42)
        .with_slots(slots);
    let cfg = SchedulerConfig { slots, refill, ..Default::default() };
    let mut core = Scheduler::new(engine, ManualClock::default(), cfg);
    let ids: Vec<u64> = prompts
        .iter()
        .zip(params)
        .map(|(p, d)| {
            let job = Job { prompt: p.clone(), params: *d, timeout_ms: None, queued_for_ms: 0 };
            core.submit(job)
        })
        .collect();
    let done = drain(&mut core);
    assert_eq!(done.len(), ids.len(), "exactly one completion per request");
    let by_id: BTreeMap<u64, Vec<u32>> = done
        .into_iter()
        .map(|c| {
            assert_eq!(c.reason, FinishReason::Done);
            (c.id, c.tokens)
        })
        .collect();
    ids.iter().map(|id| by_id[id].clone()).collect()
}

/// Acceptance: in single-slot and no-refill configurations the
/// continuous scheduler is token-for-token identical to PR 2's static
/// paths — both `NativeEngine::generate` and the `decode_batch`
/// full-recompute greedy loop — including early stop.
#[test]
fn single_slot_and_no_refill_match_static_decode() {
    let cfg = tiny();
    let weights = Weights::synthetic(&cfg, 17);
    let (b, t, vocab) = (3usize, 16usize, cfg.vocab);
    // same weights/prompts `infer_integration` pins against the XLA
    // loop; the third row re-decodes row 0's prompt under a shorter
    // budget, so mixed lengths exercise the refill path
    let prompts = vec![vec![5u32, 10, 15], vec![7u32], vec![5u32, 10, 15]];
    let params = vec![
        DecodeParams::greedy(5),
        DecodeParams::greedy(3),
        DecodeParams::greedy(4),
    ];

    // reference 1: the static decode_batch loop over full recompute
    let mut rng = Pcg32::seeded(1);
    let step = full_recompute_step(&weights, b, t, vocab);
    let reference = decode_batch(step, b, t, vocab, &prompts, &params, &mut rng).unwrap();

    // reference 2: the static Generator path on the same engine kind
    let mut static_engine = NativeEngine::new(weights.clone(), &BTreeMap::new(), t, 42);
    let static_gen = static_engine.generate(&prompts, &params).unwrap();
    assert_eq!(static_gen.outputs, reference.outputs, "PR 2 invariant must still hold");

    // continuous, single slot: requests run back to back on one cache
    let single = run_scheduled(&weights, t, 1, true, &prompts, &params);
    assert_eq!(single, reference.outputs, "single-slot scheduler != static decode");

    // continuous, multi-slot but no refill: one static wave
    let wave = run_scheduled(&weights, t, 3, false, &prompts, &params);
    assert_eq!(wave, reference.outputs, "no-refill wave != static decode");

    // and with refill on: same streams (greedy rows are
    // interleaving-independent), different scheduling
    let cont = run_scheduled(&weights, t, 2, true, &prompts, &params);
    assert_eq!(cont, reference.outputs, "refill scheduling changed a greedy stream");

    // early stop: cut row 0 at its second reference token
    let stop = reference.outputs[0][1];
    let stopping = vec![
        DecodeParams { stop: Some(stop), ..DecodeParams::greedy(5) },
        DecodeParams::greedy(3),
        DecodeParams::greedy(4),
    ];
    let mut rng = Pcg32::seeded(2);
    let step = full_recompute_step(&weights, b, t, vocab);
    let ref_stop = decode_batch(step, b, t, vocab, &prompts, &stopping, &mut rng).unwrap();
    let sched_stop = run_scheduled(&weights, t, 1, true, &prompts, &stopping);
    assert_eq!(sched_stop, ref_stop.outputs);
    assert_eq!(sched_stop[0].last(), Some(&stop), "row 0 ends at its stop token");
}

/// The whole continuous serving stack over TCP: normal replies, a
/// deterministic zero-timeout partial (flagged) reply, malformed-line
/// handling — artifact-free, so it runs in every environment.
#[test]
fn continuous_backend_serves_over_tcp() {
    use db_llm::coordinator::metrics::Metrics;

    let cfg = tiny();
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let factory_cfg = cfg.clone();
    let addr = serve_continuous(
        move || {
            let weights = Weights::synthetic(&factory_cfg, 31);
            Ok(NativeEngine::new(weights, &BTreeMap::new(), factory_cfg.seq_len, 5)
                .with_slots(2))
        },
        "127.0.0.1:0",
        64,
        SchedulerConfig { slots: 2, ..Default::default() },
        1,
        metrics.clone(),
        running.clone(),
    )
    .unwrap();

    let mut stream = loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    };
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // greedy requests are deterministic and honor their budget
    let mut responses = Vec::new();
    for _ in 0..2 {
        writeln!(stream, "{{\"prompt\": [5, 10, 15], \"max_tokens\": 6}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert!(j.opt("timeout").is_none(), "got {line}");
        let toks = j.usize_list("tokens").unwrap();
        assert_eq!(toks.len(), 6);
        assert!(toks.iter().all(|&t| t < cfg.vocab));
        responses.push(toks);
    }
    assert_eq!(responses[0], responses[1], "greedy decode must be deterministic");

    // a zero deadline deterministically yields a flagged timeout reply
    // with an empty partial result, before ever occupying a slot
    writeln!(stream, "{{\"prompt\": [1], \"max_tokens\": 4, \"timeout_ms\": 0}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert!(j.get("timeout").unwrap().as_bool().unwrap(), "got {line}");
    assert!(j.usize_list("tokens").unwrap().is_empty(), "got {line}");

    // malformed lines still get an error reply, connection stays up
    writeln!(stream, "not json").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "got {line}");
    writeln!(stream, "{{\"prompt\": [1], \"max_tokens\": 2}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("tokens"), "got {line}");

    running.store(false, std::sync::atomic::Ordering::Relaxed);
    let ord = std::sync::atomic::Ordering::Relaxed;
    assert!(metrics.responses.load(ord) >= 4);
    assert_eq!(metrics.timeouts.load(ord), 1);
    assert!(metrics.slot_ticks.load(ord) >= metrics.slot_busy_ticks.load(ord));
    assert!(metrics.slot_busy_ticks.load(ord) >= 14, "6+6+2 tokens decoded on slots");
}
