//! Speculative-decoding equivalence battery: the FDB-student /
//! dense-teacher [`SpecDecoder`] must emit greedy streams that are
//! **bit-identical** to teacher-only decode — across seeds, draft
//! lengths, staggered prefills, mid-flight refills, and rollbacks that
//! land on KV block boundaries — while the acceptance counters satisfy
//! the deterministic work model (`drafted == accepted + rejected`,
//! acceptance never exceeds `k`, every verified group emits one bonus
//! row) and the paged pool neither copies rows on rollback nor leaks
//! blocks.  The same battery drives the decoder through the continuous
//! scheduler (mixed speculative + sampled + opted-out rows) and under
//! the chaos harness, where speculation must be gated off and every
//! seeded run must replay bit-for-bit.  Everything here is
//! artifact-free and runs in every environment; CI runs this file as
//! the `spec-decode-equivalence` gate.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};

use db_llm::coordinator::chaos::{ChaosEngine, FaultPlan};
use db_llm::coordinator::scheduler::{
    Clock, Completion, FinishReason, Job, ManualClock, Scheduler, SchedulerConfig, SlotEngine,
};
use db_llm::coordinator::serve::{argmax, DecodeParams};
use db_llm::infer::{NativeEngine, SpecDecoder, DEFAULT_BLOCK_TOKENS};
use db_llm::model::{ModelConfig, Weights};
use db_llm::quant::FdbLinear;
use db_llm::util::Pcg32;

fn tiny() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 192,
        vocab: 96,
        seq_len: 32,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

/// Dense teacher from `teacher_seed`, FDB student quantized from
/// `student_seed` weights.  Same seed → a faithful (but lossy) student
/// with a real acceptance rate; different seeds → a student that
/// drafts mostly-wrong tokens, hammering the rejection/rollback path.
/// Either way the emitted stream must equal the teacher's: the student
/// is allowed to affect *speed*, never *content*.
fn build_spec(
    teacher_seed: u64,
    student_seed: u64,
    k: usize,
    slots: usize,
    window: usize,
) -> SpecDecoder {
    let cfg = tiny();
    let teacher = Weights::synthetic(&cfg, teacher_seed);
    let student = Weights::synthetic(&cfg, student_seed);
    let mut fdb = BTreeMap::new();
    for name in cfg.linear_names() {
        fdb.insert(name.clone(), FdbLinear::from_weights(student.mat(&name), 64));
    }
    SpecDecoder::new(teacher, student, &fdb, window, k).with_slots(slots)
}

/// The ground truth: a plain dense `NativeEngine` decoding the same
/// prompt greedily under the scheduler's stop/budget semantics.
fn reference_stream(
    teacher_seed: u64,
    window: usize,
    prompt: &[u32],
    budget: usize,
    stop: Option<u32>,
) -> Vec<u32> {
    let cfg = tiny();
    let mut eng = NativeEngine::new(
        Weights::synthetic(&cfg, teacher_seed),
        &BTreeMap::new(),
        window,
        42,
    )
    .with_slots(1);
    let mut logits = eng.prefill_slot(0, prompt).unwrap();
    let mut out = Vec::new();
    loop {
        let tok = argmax(&logits) as u32;
        out.push(tok);
        if out.len() >= budget || stop == Some(tok) {
            return out;
        }
        logits = eng.step_slot(0, tok).unwrap();
    }
}

/// Decode one slot to its budget through the speculative path,
/// asserting the per-group acceptance invariants on every tick.
fn spec_stream(spec: &mut SpecDecoder, slot: usize, prompt: &[u32], budget: usize) -> Vec<u32> {
    let logits = spec.prefill_slot(slot, prompt).unwrap();
    let mut last = argmax(&logits) as u32;
    let mut out = vec![last];
    while out.len() < budget {
        let groups = spec.step_slots_speculative(&[(slot, last)]).unwrap();
        assert_eq!(groups.len(), 1);
        let g = &groups[0];
        assert!(g.accepted <= g.drafted, "accepted beyond the drafts offered");
        assert_eq!(g.rows.len(), g.accepted as usize + 1, "rows != accepted + bonus");
        for row in &g.rows {
            if out.len() >= budget {
                break;
            }
            last = argmax(row) as u32;
            out.push(last);
        }
    }
    out
}

/// One speculative tick over every still-live slot; emitted rows are
/// appended to each slot's stream and exhausted slots leave `active`.
fn tick_active(
    spec: &mut SpecDecoder,
    active: &mut Vec<usize>,
    last: &mut [u32],
    got: &mut [Vec<u32>],
    budget: &[usize],
) {
    if active.is_empty() {
        return;
    }
    let live: Vec<(usize, u32)> = active.iter().map(|&s| (s, last[s])).collect();
    let groups = spec.step_slots_speculative(&live).unwrap();
    assert_eq!(groups.len(), live.len(), "one group per requested slot");
    for (i, g) in groups.iter().enumerate() {
        let slot = live[i].0;
        assert!(g.accepted <= g.drafted, "slot {slot}: accepted beyond drafts");
        assert_eq!(g.rows.len(), g.accepted as usize + 1, "slot {slot}: row count");
        for row in &g.rows {
            if got[slot].len() >= budget[slot] {
                break;
            }
            last[slot] = argmax(row) as u32;
            got[slot].push(last[slot]);
        }
    }
    active.retain(|&s| got[s].len() < budget[s]);
}

/// The headline acceptance gate: across seeds × draft lengths ×
/// staggered prefill schedules × mixed prompt lengths (several
/// straddling the KV block boundary), every speculative greedy stream
/// equals its teacher-only reference token for token, the counters
/// tally, and resetting every slot returns the pool to zero live
/// blocks with zero rows copied.
#[test]
fn speculative_streams_match_teacher_only_across_seeds_and_k() {
    let vocab = tiny().vocab;
    for seed in 1..=4u64 {
        for &k in &[1usize, 3] {
            let (slots, window) = (3usize, 32usize);
            let mut spec = build_spec(seed, seed, k, slots, window);
            let mut rng = Pcg32::seeded(seed * 131 + k as u64);

            let mut last = vec![0u32; slots];
            let mut budget = vec![0usize; slots];
            let mut got: Vec<Vec<u32>> = vec![Vec::new(); slots];
            let mut expect: Vec<Vec<u32>> = vec![Vec::new(); slots];
            let mut active: Vec<usize> = Vec::new();

            for slot in 0..slots {
                // staggered admissions: earlier slots keep speculating
                // between prefills, so every teacher cache sits at its
                // own absolute position when the batched verify runs
                let plen = rng.range(1, 18);
                let prompt: Vec<u32> = (0..plen).map(|_| rng.range(0, vocab) as u32).collect();
                budget[slot] = rng.range(4, 13);
                expect[slot] = reference_stream(seed, window, &prompt, budget[slot], None);
                let logits = spec.prefill_slot(slot, &prompt).unwrap();
                last[slot] = argmax(&logits) as u32;
                got[slot].push(last[slot]);
                active.push(slot);
                active.retain(|&s| got[s].len() < budget[s]);
                for _ in 0..rng.range(0, 3) {
                    tick_active(&mut spec, &mut active, &mut last, &mut got, &budget);
                }
            }
            let mut guard = 0;
            while !active.is_empty() {
                guard += 1;
                assert!(guard < 10_000, "seed {seed} k {k}: failed to drain");
                tick_active(&mut spec, &mut active, &mut last, &mut got, &budget);
            }

            for slot in 0..slots {
                assert_eq!(
                    got[slot], expect[slot],
                    "seed {seed} k {k} slot {slot}: speculative stream diverged"
                );
            }
            let c = spec.counters();
            assert_eq!(c.drafted, c.accepted + c.rejected, "seed {seed} k {k}: tally broken");
            assert!(c.drafted > 0, "seed {seed} k {k}: speculation never engaged");
            spec.assert_invariants();
            assert_eq!(spec.kv_pool().stats().copied_rows, 0, "rollback must never copy rows");
            for slot in 0..slots {
                spec.reset_slot(slot);
            }
            assert_eq!(spec.kv_pool().stats().live_blocks, 0, "seed {seed} k {k}: leaked blocks");
        }
    }
}

/// Rollback landing on KV block boundaries: prompt lengths straddling
/// `DEFAULT_BLOCK_TOKENS` with a deliberately mismatched student (a
/// different weight seed), so nearly every tick rejects drafts and
/// truncates the block table right around a boundary.  Streams stay
/// bit-exact, truncation never copies rows, and resets free everything.
#[test]
fn rollback_at_block_boundaries_is_exact_and_copy_free() {
    let bt = DEFAULT_BLOCK_TOKENS;
    let mut total_rejected = 0u64;
    let mut total_rolled = 0u64;
    for plen in (bt - 2)..=(bt + 1) {
        let mut spec = build_spec(21, 99, 4, 1, 32);
        let prompt: Vec<u32> = (0..plen as u32).map(|i| (i * 5 + 3) % 96).collect();
        let got = spec_stream(&mut spec, 0, &prompt, 8);
        let expect = reference_stream(21, 32, &prompt, 8, None);
        assert_eq!(got, expect, "plen {plen}: stream diverged across the block boundary");
        let c = spec.counters();
        assert_eq!(c.drafted, c.accepted + c.rejected, "plen {plen}: tally broken");
        total_rejected += c.rejected;
        total_rolled += c.rolled_back_rows;
        assert_eq!(spec.kv_pool().stats().copied_rows, 0, "plen {plen}: rollback copied rows");
        spec.assert_invariants();
        spec.reset_slot(0);
        assert_eq!(spec.kv_pool().stats().live_blocks, 0, "plen {plen}: leaked blocks");
    }
    assert!(total_rejected > 0, "a mismatched student must get drafts rejected");
    assert!(total_rolled > 0, "rejections must roll cache rows back");
}

/// A slot that finishes and is refilled mid-flight re-enters the
/// speculative batch cleanly: the refilled stream and the undisturbed
/// neighbour both stay bit-exact.
#[test]
fn mid_flight_refill_keeps_speculative_streams_exact() {
    let window = 32usize;
    let mut spec = build_spec(9, 9, 3, 2, window);
    let p0: Vec<u32> = vec![4, 9, 14];
    let p1: Vec<u32> = vec![7, 1, 22, 5];
    let p2: Vec<u32> = vec![42, 17];
    let (b0, b1, b2) = (4usize, 12usize, 5usize);
    let e0 = reference_stream(9, window, &p0, b0, None);
    let e1 = reference_stream(9, window, &p1, b1, None);
    let e2 = reference_stream(9, window, &p2, b2, None);

    let mut last = vec![0u32; 2];
    let mut budget = vec![b0, b1];
    let mut got: Vec<Vec<u32>> = vec![Vec::new(), Vec::new()];
    let mut active = vec![0usize, 1];
    for (slot, p) in [&p0, &p1].into_iter().enumerate() {
        let logits = spec.prefill_slot(slot, p).unwrap();
        last[slot] = argmax(&logits) as u32;
        got[slot].push(last[slot]);
    }
    let mut guard = 0;
    while active.contains(&0) {
        guard += 1;
        assert!(guard < 10_000, "slot 0 failed to drain");
        tick_active(&mut spec, &mut active, &mut last, &mut got, &budget);
    }
    assert_eq!(got[0], e0, "pre-refill stream diverged");

    // slot 0 finishes and is refilled while slot 1 keeps speculating
    spec.reset_slot(0);
    let g0 = std::mem::take(&mut got[0]);
    assert_eq!(g0, e0);
    let logits = spec.prefill_slot(0, &p2).unwrap();
    last[0] = argmax(&logits) as u32;
    got[0].push(last[0]);
    budget[0] = b2;
    active.push(0);
    active.retain(|&s| got[s].len() < budget[s]);

    let mut guard = 0;
    while !active.is_empty() {
        guard += 1;
        assert!(guard < 10_000, "post-refill drain stalled");
        tick_active(&mut spec, &mut active, &mut last, &mut got, &budget);
    }
    assert_eq!(got[0], e2, "refilled stream diverged");
    assert_eq!(got[1], e1, "the neighbour was perturbed by the refill");
    spec.assert_invariants();
    spec.reset_slot(0);
    spec.reset_slot(1);
    assert_eq!(spec.kv_pool().stats().live_blocks, 0, "refill cycle leaked blocks");
}

/// Property soak: random seeds, draft lengths, slot counts, prompts,
/// and per-tick slot subsets.  On every tick the per-group invariants
/// hold (`accepted ≤ drafted ≤ k`, `rows == accepted + 1`) and the
/// counter deltas match the groups exactly; at the end the global
/// tally holds and the pool audits clean with zero leaks.
#[test]
fn acceptance_invariants_hold_under_random_schedules() {
    for seed in 1..=8u64 {
        let mut rng = Pcg32::seeded(seed * 7 + 1);
        let k = rng.range(1, 6);
        let slots = rng.range(1, 4);
        let mut spec = build_spec(seed, seed ^ 0x5a, k, slots, 32);
        let mut last = vec![0u32; slots];
        for slot in 0..slots {
            let plen = rng.range(1, 20);
            let prompt: Vec<u32> = (0..plen).map(|_| rng.range(0, 96) as u32).collect();
            let logits = spec.prefill_slot(slot, &prompt).unwrap();
            last[slot] = argmax(&logits) as u32;
        }
        for round in 0..12 {
            let subset: Vec<(usize, u32)> = (0..slots)
                .filter(|s| slots == 1 || (s + round) % 2 == 0 || rng.f32() < 0.5)
                .map(|s| (s, last[s]))
                .collect();
            if subset.is_empty() {
                continue;
            }
            let before = spec.counters();
            let groups = spec.step_slots_speculative(&subset).unwrap();
            let after = spec.counters();

            let (mut drafted, mut accepted, mut drafting_groups) = (0u64, 0u64, 0u64);
            for (i, g) in groups.iter().enumerate() {
                assert!(g.accepted <= g.drafted, "seed {seed}: accepted beyond drafts");
                assert!(g.drafted as usize <= k, "seed {seed}: drafted beyond k");
                assert_eq!(g.rows.len(), g.accepted as usize + 1, "seed {seed}: row count");
                drafted += u64::from(g.drafted);
                accepted += u64::from(g.accepted);
                drafting_groups += u64::from(g.drafted > 0);
                last[subset[i].0] = argmax(g.rows.last().unwrap()) as u32;
            }
            assert_eq!(after.drafted - before.drafted, drafted, "seed {seed}: drafted delta");
            assert_eq!(after.accepted - before.accepted, accepted, "seed {seed}: accepted delta");
            assert_eq!(
                after.rejected - before.rejected,
                drafted - accepted,
                "seed {seed}: rejected delta"
            );
            assert_eq!(
                after.bonus - before.bonus,
                drafting_groups,
                "seed {seed}: one bonus per verified group"
            );
            if round % 4 == 0 {
                spec.assert_invariants();
            }
        }
        let c = spec.counters();
        assert_eq!(c.drafted, c.accepted + c.rejected, "seed {seed}: global tally");
        spec.assert_invariants();
        for slot in 0..slots {
            spec.reset_slot(slot);
        }
        assert_eq!(spec.kv_pool().stats().live_blocks, 0, "seed {seed}: leaked blocks");
        assert_eq!(spec.kv_pool().stats().copied_rows, 0, "seed {seed}: copied rows");
    }
}

// ---------------------------------------------------------------------
// Scheduler integration: speculative slots behind the continuous core
// ---------------------------------------------------------------------

fn drain<E: SlotEngine, C: Clock>(core: &mut Scheduler<E, C>) -> Vec<Completion> {
    let mut out = Vec::new();
    let mut guard = 0;
    while !core.is_idle() {
        out.extend(core.tick());
        core.assert_invariants();
        guard += 1;
        assert!(guard < 100_000, "scheduler failed to drain");
    }
    out
}

/// Run `jobs` to completion and give back each request's stream in
/// submission order plus the scheduler's speculative counters
/// `[drafted, accepted, rejected, bonus, fallback_rows]`.
fn run_jobs<E: SlotEngine>(
    engine: E,
    slots: usize,
    jobs: &[(Vec<u32>, DecodeParams)],
) -> (Vec<Vec<u32>>, [u64; 5]) {
    let cfg = SchedulerConfig { slots, ..Default::default() };
    let mut core = Scheduler::new(engine, ManualClock::default(), cfg);
    let ids: Vec<u64> = jobs
        .iter()
        .map(|(p, d)| {
            core.submit(Job { prompt: p.clone(), params: *d, timeout_ms: None, queued_for_ms: 0 })
        })
        .collect();
    let done = drain(&mut core);
    assert_eq!(done.len(), ids.len(), "exactly one completion per request");
    let by_id: BTreeMap<u64, Vec<u32>> = done
        .into_iter()
        .map(|c| {
            assert_eq!(c.reason, FinishReason::Done);
            (c.id, c.tokens)
        })
        .collect();
    let s = &core.stats;
    (
        ids.iter().map(|id| by_id[id].clone()).collect(),
        [s.spec_drafted, s.spec_accepted, s.spec_rejected, s.spec_bonus, s.spec_fallback_rows],
    )
}

/// The serving-level equivalence gate: the continuous scheduler over a
/// `SpecDecoder` answers greedy requests (mixed lengths, refills, an
/// early stop token) token-for-token identically to the same scheduler
/// over a plain dense `NativeEngine` — and opting rows out via
/// `speculate: false` keeps the streams while drafting nothing.
#[test]
fn scheduler_speculative_streams_equal_plain_scheduler() {
    let (seed, window, slots) = (11u64, 32usize, 2usize);
    let cfg = tiny();
    let prompts: Vec<Vec<u32>> = vec![
        vec![5, 10, 15],
        vec![7],
        (0..16u32).map(|i| (i * 3 + 1) % 96).collect(),
        vec![33, 2],
        vec![5, 10, 15],
    ];
    let budgets = [6usize, 4, 8, 10, 5];
    // job 0 stops early at its reference stream's second token
    let stop = reference_stream(seed, window, &prompts[0], budgets[0], None)[1];
    let jobs: Vec<(Vec<u32>, DecodeParams)> = prompts
        .iter()
        .zip(budgets)
        .enumerate()
        .map(|(i, (p, b))| {
            let stop = (i == 0).then_some(stop);
            (p.clone(), DecodeParams { stop, ..DecodeParams::greedy(b) })
        })
        .collect();

    let native =
        NativeEngine::new(Weights::synthetic(&cfg, seed), &BTreeMap::new(), window, 42)
            .with_slots(slots);
    let (reference, z) = run_jobs(native, slots, &jobs);
    assert_eq!(z, [0; 5], "a plain engine must never report speculative work");
    assert_eq!(reference[0].last(), Some(&stop), "job 0 must stop early");

    let spec = build_spec(seed, seed, 3, slots, window);
    let (streams, s) = run_jobs(spec, slots, &jobs);
    assert_eq!(streams, reference, "speculative scheduler changed a greedy stream");
    assert_eq!(s[0], s[1] + s[2], "drafted != accepted + rejected at the scheduler");
    assert!(s[0] > 0, "speculation never engaged under the scheduler");
    // every drafting group offers exactly k drafts and earns one bonus
    assert_eq!(s[3] * 3, s[0], "bonus groups × k must equal drafted");

    // opt-out: same jobs flagged speculate=false draft nothing and
    // still match the reference exactly
    let opted: Vec<(Vec<u32>, DecodeParams)> = jobs
        .iter()
        .map(|(p, d)| (p.clone(), DecodeParams { speculate: false, ..*d }))
        .collect();
    let spec = build_spec(seed, seed, 3, slots, window);
    let (streams, s) = run_jobs(spec, slots, &opted);
    assert_eq!(streams, reference, "opted-out rows changed a stream");
    assert_eq!(s[0], 0, "opted-out rows must not draft");
}

/// Sampled rows coexist with speculative rows in the same scheduler:
/// greedy requests keep their exact teacher streams while a
/// temperature-sampled request decodes its full budget on the plain
/// fused path of the same engine.
#[test]
fn mixed_sampled_and_speculative_rows_coexist() {
    let (seed, window, slots) = (23u64, 32usize, 2usize);
    let greedy_prompt = vec![3u32, 44, 8];
    let expect = reference_stream(seed, window, &greedy_prompt, 7, None);
    let jobs: Vec<(Vec<u32>, DecodeParams)> = vec![
        (greedy_prompt.clone(), DecodeParams::greedy(7)),
        (vec![9, 61], DecodeParams { temperature: 0.8, ..DecodeParams::greedy(6) }),
        (greedy_prompt, DecodeParams::greedy(7)),
    ];
    let spec = build_spec(seed, seed, 3, slots, window);
    let (streams, s) = run_jobs(spec, slots, &jobs);
    assert_eq!(streams[0], expect, "greedy stream perturbed by a sampled neighbour");
    assert_eq!(streams[2], expect, "greedy streams must agree with each other");
    assert_eq!(streams[1].len(), 6, "the sampled request must decode its full budget");
    assert!(streams[1].iter().all(|&t| (t as usize) < tiny().vocab));
    assert_eq!(s[0], s[1] + s[2], "tally must hold with mixed rows");
    assert!(s[0] > 0, "the greedy rows must still speculate");
}

// ---------------------------------------------------------------------
// Chaos: speculation under the fault-injection harness
// ---------------------------------------------------------------------

/// One seeded chaos soak over a chaos-wrapped `SpecDecoder` driven by
/// the scheduler core, with the supervisor's recovery sequence on
/// scripted panics.  Returns each request's outcome in submission
/// order (tokens, or the error string).
fn run_chaos_soak(seed: u64) -> Vec<Result<Vec<u32>, String>> {
    let spec = build_spec(3, 3, 3, 2, 32);
    let pool = spec.kv_pool().clone();
    let engine = ChaosEngine::new(spec, FaultPlan::random(seed, 120, 3));
    assert_eq!(engine.speculate_k(), 0, "chaos must pin speculation off");
    assert!(engine.spec_counters().is_none(), "a gated engine reports no spec counters");
    let mut core = Scheduler::new(
        engine,
        ManualClock::default(),
        SchedulerConfig { slots: 2, seed, ..SchedulerConfig::default() },
    );
    let ids: Vec<u64> = (0..10u32)
        .map(|i| {
            core.submit(Job {
                prompt: vec![(i * 7 + 3) % 96, (i + 1) % 96],
                params: DecodeParams::greedy(4),
                timeout_ms: None,
                queued_for_ms: 0,
            })
        })
        .collect();
    let mut done: Vec<Completion> = Vec::new();
    let mut guard = 0;
    while done.len() < ids.len() {
        guard += 1;
        assert!(guard < 100_000, "seed {seed}: chaos soak failed to drain");
        match catch_unwind(AssertUnwindSafe(|| core.tick())) {
            Ok(c) => done.extend(c),
            Err(_) => {
                let (dead, _quarantined) = core.recover_after_panic("worker panicked: chaos");
                done.extend(dead);
                core.engine_mut().recover().expect("engine recovery after a scripted panic");
            }
        }
    }
    assert_eq!(done.len(), ids.len(), "seed {seed}: a request was answered twice");
    assert_eq!(core.stats.spec_drafted, 0, "seed {seed}: a gated engine must draft nothing");
    core.assert_invariants();
    drop(core);
    assert_eq!(pool.stats().live_blocks, 0, "seed {seed}: chaos leaked KV blocks");
    pool.assert_invariants();

    let by_id: BTreeMap<u64, Result<Vec<u32>, String>> = done
        .into_iter()
        .map(|c| {
            let out = match &c.reason {
                FinishReason::Done => Ok(c.tokens.clone()),
                FinishReason::Error(m) => Err(m.clone()),
                other => Err(format!("unexpected finish: {other:?}")),
            };
            (c.id, out)
        })
        .collect();
    let transcript: Vec<Result<Vec<u32>, String>> =
        ids.iter().map(|id| by_id[id].clone()).collect();
    dump_transcript(
        &format!("spec_chaos seed={seed}"),
        transcript.iter().enumerate().map(|(i, r)| format!("req={i} {r:?}")),
    );
    transcript
}

/// Flake-detector hook: when `DBLLM_TRANSCRIPT_DUMP` names a file,
/// append every seeded transcript line to it.  CI runs the suite twice
/// single-threaded and byte-diffs the two dumps, so any nondeterminism
/// in the seeded soaks surfaces as a diff even when both runs pass.
fn dump_transcript(tag: &str, lines: impl IntoIterator<Item = String>) {
    let Ok(path) = std::env::var("DBLLM_TRANSCRIPT_DUMP") else { return };
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .expect("transcript dump file must be writable");
    for l in lines {
        writeln!(f, "{tag}: {l}").expect("transcript dump write");
    }
}

/// Satellite: the chaos wrapper keeps its 1:1 fault-ordinal mapping by
/// gating speculation off entirely — a wrapped `SpecDecoder` decodes
/// plain, deterministically, and replaying a seed reproduces the
/// transcript bit for bit while clean requests match the teacher-only
/// stream.
#[test]
fn chaos_gates_speculation_and_replays_bit_identically() {
    for seed in [2u64, 5] {
        let first = run_chaos_soak(seed);
        let replay = run_chaos_soak(seed);
        assert_eq!(first, replay, "seed {seed}: chaos replay diverged");
        let mut clean = 0usize;
        for (i, outcome) in first.iter().enumerate() {
            match outcome {
                Ok(tokens) => {
                    let i = i as u32;
                    let prompt = vec![(i * 7 + 3) % 96, (i + 1) % 96];
                    let expect = reference_stream(3, 32, &prompt, 4, None);
                    assert_eq!(
                        tokens, &expect,
                        "seed {seed}: clean request {i} diverged from teacher-only decode"
                    );
                    clean += 1;
                }
                Err(e) => assert!(
                    e.contains("chaos") || e.contains("panicked"),
                    "seed {seed}: request {i} failed outside the plan: {e}"
                ),
            }
        }
        assert!(clean > 0, "seed {seed}: every request was injected — nothing verified");
    }
}
