//! Fused-vs-sequential decode equivalence: the batched
//! `SlotEngine::step_slots` path (one GEMM per linear per tick) must
//! produce *bit-identical* logits — and therefore token-for-token
//! identical greedy streams — to looping `step_slot` over the same
//! slots.  The property is exercised across seeds, mixed prompt
//! lengths, staggered prefills (so every row sits at its own absolute
//! position), shifting active-slot subsets, and FDB-vs-dense layer
//! mixes.  Everything here is artifact-free and runs in every
//! environment.

use std::collections::BTreeMap;

use db_llm::coordinator::scheduler::SlotEngine;
use db_llm::coordinator::serve::argmax;
use db_llm::infer::NativeEngine;
use db_llm::model::{ModelConfig, Weights};
use db_llm::quant::FdbLinear;
use db_llm::util::Pcg32;

fn tiny() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 192,
        vocab: 96,
        seq_len: 32,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

/// Build an engine; `fdb_stride` compiles every `stride`-th linear to
/// the sparse FDB kernel (None = all dense, Some(1) = the full paper
/// student), so the sweep covers dense, mixed, and fully-binarized
/// layer stacks.
fn build(seed: u64, slots: usize, fdb_stride: Option<usize>) -> NativeEngine {
    let cfg = tiny();
    let w = Weights::synthetic(&cfg, seed);
    let mut fdb = BTreeMap::new();
    if let Some(stride) = fdb_stride {
        for (i, name) in cfg.linear_names().iter().enumerate() {
            if i % stride == 0 {
                fdb.insert(name.clone(), FdbLinear::from_weights(w.mat(name), 64));
            }
        }
    }
    NativeEngine::new(w, &fdb, cfg.seq_len, 7).with_slots(slots)
}

/// Advance `active` on both engines — sequential `step_slot` loop on
/// `seq`, one batched `step_slots` call on `fus` — asserting the
/// logits rows and the greedy tokens they induce are identical.
fn step_both(
    seq: &mut NativeEngine,
    fus: &mut NativeEngine,
    active: &[usize],
    last: &mut [u32],
) {
    if active.is_empty() {
        return;
    }
    let steps: Vec<(usize, u32)> = active.iter().map(|&s| (s, last[s])).collect();
    let mut reference = Vec::with_capacity(steps.len());
    for &(slot, token) in &steps {
        reference.push(seq.step_slot(slot, token).unwrap());
    }
    let fused = fus.step_slots(&steps).unwrap();
    seq.assert_invariants();
    fus.assert_invariants();
    assert_eq!(fused.len(), steps.len());
    for (i, &slot) in active.iter().enumerate() {
        assert_eq!(
            reference[i], fused[i],
            "slot {slot}: fused logits diverge from sequential"
        );
        last[slot] = argmax(&fused[i]) as u32;
    }
}

/// The acceptance property: across seeds, prompt lengths, staggered
/// prefill schedules and FDB/dense mixes, fused and sequential decode
/// agree bit-for-bit on every logits row of every greedy stream.
#[test]
fn fused_step_slots_matches_sequential_streams() {
    let vocab = tiny().vocab;
    for seed in 1..=4u64 {
        for fdb_stride in [None, Some(2), Some(1)] {
            let slots = 4usize;
            let mut seq = build(seed, slots, fdb_stride);
            let mut fus = build(seed, slots, fdb_stride);
            let mut rng = Pcg32::seeded(seed * 97 + 3);

            let mut last = vec![0u32; slots];
            let mut active: Vec<usize> = Vec::new();
            for slot in 0..slots {
                // mixed prompt lengths, admitted mid-flight: earlier
                // slots keep stepping between admissions, so every row
                // ends up at its own absolute position
                let plen = rng.range(1, 7);
                let prompt: Vec<u32> =
                    (0..plen).map(|_| rng.range(0, vocab) as u32).collect();
                let a = seq.prefill_slot(slot, &prompt).unwrap();
                let b = fus.prefill_slot(slot, &prompt).unwrap();
                assert_eq!(a, b, "prefill logits diverge on slot {slot}");
                last[slot] = argmax(&b) as u32;
                active.push(slot);
                for _ in 0..rng.range(0, 3) {
                    step_both(&mut seq, &mut fus, &active, &mut last);
                }
            }
            // steady state: the full batch decodes together
            for _ in 0..8 {
                step_both(&mut seq, &mut fus, &active, &mut last);
            }
            // partial batches: only a shifting subset of slots steps,
            // the rest keep their state frozen in both engines
            for round in 0..4 {
                let subset: Vec<usize> =
                    (0..slots).filter(|s| (s + round) % 2 == 0).collect();
                step_both(&mut seq, &mut fus, &subset, &mut last);
            }
        }
    }
}

/// Refilled slots re-enter the batch cleanly: resetting and
/// re-prefilling one slot mid-flight must not perturb the fused
/// neighbours, and the refilled row fuses back in at its new position.
#[test]
fn fused_batch_survives_mid_flight_refill() {
    let slots = 3usize;
    let mut seq = build(9, slots, Some(2));
    let mut fus = build(9, slots, Some(2));
    let mut last = vec![0u32; slots];
    for slot in 0..slots {
        let prompt: Vec<u32> = (1..=(slot as u32 + 2)).collect();
        let a = seq.prefill_slot(slot, &prompt).unwrap();
        let b = fus.prefill_slot(slot, &prompt).unwrap();
        assert_eq!(a, b);
        last[slot] = argmax(&b) as u32;
    }
    let all: Vec<usize> = (0..slots).collect();
    for _ in 0..3 {
        step_both(&mut seq, &mut fus, &all, &mut last);
    }
    // slot 1 finishes and is refilled with a fresh prompt
    seq.reset_slot(1);
    fus.reset_slot(1);
    let a = seq.prefill_slot(1, &[42, 17]).unwrap();
    let b = fus.prefill_slot(1, &[42, 17]).unwrap();
    seq.assert_invariants();
    fus.assert_invariants();
    assert_eq!(a, b, "refill prefill diverged");
    last[1] = argmax(&b) as u32;
    for _ in 0..4 {
        step_both(&mut seq, &mut fus, &all, &mut last);
    }
}
