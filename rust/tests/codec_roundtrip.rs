//! Property-driven roundtrip suite for the entropy-coding stack: every
//! payload class the serving path can emit — random bytes, empty input,
//! single-symbol streams, worst-case incompressible data, and the
//! rle→huffman composition used by `compress_plane_bytes` — must decode
//! back bit-identically, and malformed/truncated containers must error
//! instead of returning garbage.

use db_llm::codec::{bitio, byte_entropy, huffman, rle};
use db_llm::util::{prop, Pcg32};

/// Payload generators covering the distribution corners: uniform noise,
/// sparse zero-dominated planes, skewed alphabets, and tiny alphabets.
fn gen_payload(rng: &mut Pcg32) -> Vec<u8> {
    let n = rng.range(0, 4000);
    match rng.below(4) {
        0 => (0..n).map(|_| rng.next_u32() as u8).collect(),
        1 => {
            let density = rng.f32() * 0.3;
            (0..n)
                .map(|_| if rng.f32() < density { rng.next_u32() as u8 } else { 0 })
                .collect()
        }
        2 => {
            let alpha = rng.range(1, 6) as i32;
            (0..n).map(|_| (rng.f32().powi(alpha) * 255.0) as u8).collect()
        }
        _ => {
            let k = rng.range(1, 4) as u32;
            (0..n).map(|_| rng.below(k) as u8).collect()
        }
    }
}

#[test]
fn huffman_roundtrips_every_payload_class() {
    prop::check(40, |rng| {
        let data = gen_payload(rng);
        let enc = huffman::encode(&data);
        let dec = huffman::decode(&enc).unwrap();
        assert_eq!(dec, data, "huffman roundtrip broke at n={}", data.len());
    });
}

#[test]
fn rle_roundtrips_every_payload_class() {
    prop::check(40, |rng| {
        let data = gen_payload(rng);
        let enc = rle::encode(&data);
        let dec = rle::decode(&enc).unwrap();
        assert_eq!(dec, data, "rle roundtrip broke at n={}", data.len());
    });
}

#[test]
fn rle_then_huffman_composes() {
    // the exact pipeline compress_plane_bytes scores: rle → huffman →
    // huffman⁻¹ → rle⁻¹ must be the identity
    prop::check(30, |rng| {
        let data = gen_payload(rng);
        let enc = huffman::encode(&rle::encode(&data));
        let dec = rle::decode(&huffman::decode(&enc).unwrap()).unwrap();
        assert_eq!(dec, data);
    });
}

#[test]
fn empty_input_roundtrips_everywhere() {
    assert_eq!(huffman::decode(&huffman::encode(&[])).unwrap(), Vec::<u8>::new());
    assert_eq!(rle::decode(&rle::encode(&[])).unwrap(), Vec::<u8>::new());
    assert!(rle::encode(&[]).is_empty());
}

#[test]
fn single_symbol_streams_roundtrip() {
    // degenerate alphabet: the canonical code is a single 1-bit code
    prop::check(20, |rng| {
        let sym = rng.next_u32() as u8;
        let n = rng.range(1, 5000);
        let data = vec![sym; n];
        assert_eq!(huffman::decode(&huffman::encode(&data)).unwrap(), data);
        assert_eq!(rle::decode(&rle::encode(&data)).unwrap(), data);
    });
}

#[test]
fn incompressible_payloads_roundtrip_with_bounded_expansion() {
    // worst case for both coders: near-8-bit-entropy noise.  The
    // container must still roundtrip, and the size overhead must stay
    // a small constant factor (header + flat 8-bit codes).
    prop::check(10, |rng| {
        let n = rng.range(512, 8192);
        let data: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        // small-sample bias pulls empirical H below 8 by roughly
        // 255/(2n·ln2) ≈ 0.36 bits at n=512, so gate well under that
        let h = byte_entropy(&data);
        assert!(h > 7.3, "noise generator should be near-uniform, got H={h:.2}");
        let enc = huffman::encode(&data);
        assert_eq!(huffman::decode(&enc).unwrap(), data);
        assert!(
            enc.len() < data.len() + data.len() / 8 + 600,
            "expansion too large: {} -> {}",
            data.len(),
            enc.len()
        );
        // rle on zero-free data is exactly the identity on length
        let r = rle::encode(&data);
        assert!(r.len() <= data.len() + 2 * data.iter().filter(|&&b| b == 0).count());
    });
}

#[test]
fn truncated_huffman_containers_error() {
    prop::check(20, |rng| {
        let mut data = gen_payload(rng);
        if data.is_empty() {
            data.push(7);
        }
        let enc = huffman::encode(&data);
        // chop anywhere strictly inside the container: decode must not
        // succeed-and-return-wrong — either Err or (for payload-tail
        // chops that keep all coded bits) the exact original
        let cut = rng.range(0, enc.len());
        match huffman::decode(&enc[..cut]) {
            Err(_) => {}
            Ok(out) => assert_eq!(out, data, "truncated decode returned wrong bytes"),
        }
    });
}

#[test]
fn corrupted_rle_markers_error_not_panic() {
    // dangling zero marker and zero-length runs are the two malformed
    // shapes; both must surface as Err
    assert!(rle::decode(&[1, 2, 3, 0]).is_err());
    assert!(rle::decode(&[0, 0]).is_err());
    // random blobs may or may not be valid streams but must never panic
    prop::check(20, |rng| {
        let n = rng.range(0, 512);
        let blob: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let _ = rle::decode(&blob);
    });
}

#[test]
fn bitio_boundary_conditions() {
    // exact-byte, byte+1 and byte-1 bit counts across the flush boundary
    prop::check(30, |rng| {
        let n_bits = rng.range(0, 200);
        let bits: Vec<bool> = (0..n_bits).map(|_| rng.below(2) == 1).collect();
        let mut w = bitio::BitWriter::new();
        for &b in &bits {
            w.push_bit(b);
        }
        let (bytes, bit_len) = w.finish();
        assert_eq!(bit_len, n_bits);
        assert_eq!(bytes.len(), n_bits.div_ceil(8));
        let mut r = bitio::BitReader::new(&bytes, bit_len);
        assert_eq!(r.remaining(), n_bits);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(r.read_bit(), Some(b), "bit {i} of {n_bits}");
        }
        assert_eq!(r.read_bit(), None, "must stop exactly at bit_len");
        assert_eq!(r.remaining(), 0);
    });
}

#[test]
fn bitio_reader_clamps_to_buffer() {
    // a bit_len larger than the buffer must clamp, never over-read
    let bytes = [0b1010_0000u8];
    let mut r = bitio::BitReader::new(&bytes, 1000);
    let mut n = 0;
    while r.read_bit().is_some() {
        n += 1;
    }
    assert_eq!(n, 8);
}

#[test]
fn bitio_push_code_matches_bitwise_push() {
    prop::check(20, |rng| {
        let codes: Vec<(u32, u8)> = (0..rng.range(1, 64))
            .map(|_| {
                let len = rng.range(1, 25) as u8;
                let code = rng.next_u32() & ((1u32 << len) - 1);
                (code, len)
            })
            .collect();
        let mut a = bitio::BitWriter::new();
        let mut b = bitio::BitWriter::new();
        for &(c, l) in &codes {
            a.push_code(c, l);
            for i in (0..l).rev() {
                b.push_bit((c >> i) & 1 == 1);
            }
        }
        assert_eq!(a.finish(), b.finish());
    });
}
