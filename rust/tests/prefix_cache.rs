//! Cross-request prefix sharing: warm (cached-prefix) prefill must be
//! **bit-identical** to cold prefill — logits, K/V rows and therefore
//! whole greedy token streams — plus the cache-policy edge cases:
//! eviction under budget pressure mid-decode, two requests racing the
//! same cold prefix across worker threads, and a cached-prefix request
//! whose suffix is empty (prefix == full prompt).
//!
//! Everything is artifact-free (synthetic weights) and runs in every
//! environment; CI runs this file as a named gate.

use std::collections::BTreeMap;
use std::sync::{Arc, Barrier, Mutex};

use db_llm::coordinator::scheduler::{
    FinishReason, Job, ManualClock, SchedStats, Scheduler, SchedulerConfig, SlotEngine,
};
use db_llm::coordinator::serve::DecodeParams;
use db_llm::infer::{NativeEngine, PrefixCache};
use db_llm::model::{ModelConfig, Weights};
use db_llm::quant::FdbLinear;

fn tiny() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 192,
        vocab: 96,
        seq_len: 32,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

/// Half the linears on the compiled FDB sparse kernel — the paper's
/// decode path must share prefixes bit-identically too.
fn half_fdb(cfg: &ModelConfig, w: &Weights) -> BTreeMap<String, FdbLinear> {
    let mut fdb = BTreeMap::new();
    for (i, name) in cfg.linear_names().iter().enumerate() {
        if i % 2 == 0 {
            fdb.insert(name.clone(), FdbLinear::from_weights(w.mat(name), 64));
        }
    }
    fdb
}

fn engine(w: &Weights, fdb: &BTreeMap<String, FdbLinear>, slots: usize) -> NativeEngine {
    NativeEngine::new(w.clone(), fdb, tiny().seq_len, 42).with_slots(slots)
}

/// Drain `jobs` through a fresh scheduler over `engine`; returns each
/// job's greedy stream (in submit order) plus the final stats.
fn run_sched(
    engine: NativeEngine,
    jobs: &[Vec<u32>],
    budget: usize,
) -> (Vec<Vec<u32>>, SchedStats) {
    let cfg = SchedulerConfig { slots: SlotEngine::slots(&engine).min(2), ..Default::default() };
    let mut core = Scheduler::new(engine, ManualClock::default(), cfg);
    let ids: Vec<u64> = jobs
        .iter()
        .map(|p| {
            core.submit(Job {
                prompt: p.clone(),
                params: DecodeParams::greedy(budget),
                timeout_ms: None,
                queued_for_ms: 0,
            })
        })
        .collect();
    let mut out = vec![Vec::new(); jobs.len()];
    let mut guard = 0;
    while !core.is_idle() {
        core.assert_invariants();
        for c in core.tick() {
            assert_eq!(c.reason, FinishReason::Done, "unexpected completion {:?}", c.reason);
            let idx = ids.iter().position(|&i| i == c.id).unwrap();
            out[idx] = c.tokens;
        }
        guard += 1;
        assert!(guard < 10_000, "scheduler failed to drain");
    }
    (out, core.stats)
}

/// The acceptance gate: the same shared-prefix traffic drained through
/// a prefix-cached engine and a cold one produces **identical** greedy
/// token streams, while the warm run demonstrably skipped prefill work
/// (prefix_hit_tokens > 0).  Mixed FDB/dense layers, continuous
/// batching with refills, 2 slots.
#[test]
fn warm_vs_cold_greedy_streams_are_bit_identical() {
    let cfg = tiny();
    let w = Weights::synthetic(&cfg, 61);
    let fdb = half_fdb(&cfg, &w);
    // 12-token shared prefix (3 blocks of 4) + distinct suffixes; one
    // prompt is exactly the shared prefix (empty-suffix edge case goes
    // through the same traffic mix)
    let prefix: Vec<u32> = (0..12u32).map(|i| (i * 5) % cfg.vocab as u32).collect();
    let jobs: Vec<Vec<u32>> = vec![
        prefix.iter().copied().chain([70, 71]).collect(),
        prefix.iter().copied().chain([80]).collect(),
        prefix.clone(),
        prefix.iter().copied().chain([90, 91, 92]).collect(),
        prefix.iter().copied().chain([70, 71]).collect(), // exact repeat
    ];

    let (cold, cold_stats) = run_sched(engine(&w, &fdb, 2), &jobs, 6);
    let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
    let warm_engine = engine(&w, &fdb, 2).with_prefix_cache(pc.clone());
    let (warm, warm_stats) = run_sched(warm_engine, &jobs, 6);

    assert_eq!(warm, cold, "warm and cold greedy streams diverge");
    assert!(cold.iter().all(|s| s.len() == 6), "every request decoded its budget");
    assert_eq!(cold_stats.prefix_hit_tokens, 0, "cold engine reports no prefix traffic");
    assert_eq!(cold_stats.prefix_miss_tokens, 0);
    assert!(
        warm_stats.prefix_hit_tokens >= 3 * 12,
        "at least the 3 later full-prefix requests should hit all 12 prefix tokens, got {}",
        warm_stats.prefix_hit_tokens
    );
    assert!(warm_stats.prefix_miss_tokens > 0, "suffixes still pay prefill");
    let g = pc.lock().unwrap();
    g.assert_invariants();
    assert!(g.entries() >= 3, "the shared prefix's blocks are resident");
    assert!(g.used_bytes() <= 1 << 20);
}

/// Empty-suffix edge case in isolation: a prompt that is *exactly* a
/// fully-cached prefix (a multiple of the block size) must still
/// produce logits — the cache holds back the last block so the model
/// always runs ≥ 1 suffix token — and stay bit-identical to cold.
#[test]
fn full_prompt_prefix_hit_keeps_a_nonempty_suffix() {
    let cfg = tiny();
    let w = Weights::synthetic(&cfg, 67);
    let fdb = half_fdb(&cfg, &w);
    let prompt: Vec<u32> = (0..16u32).collect(); // exactly 4 blocks of 4

    let mut cold = engine(&w, &fdb, 1);
    let a = cold.prefill_slot(0, &prompt).unwrap();

    let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
    let mut warm = engine(&w, &fdb, 2).with_prefix_cache(pc.clone());
    let b = warm.prefill_slot(0, &prompt).unwrap(); // cold publish
    let c = warm.prefill_slot(1, &prompt).unwrap(); // full-prompt hit
    assert_eq!(a, b);
    assert_eq!(a, c, "empty-suffix warm prefill diverges from cold");
    // 4 blocks published, but only 3 may match (suffix rule): the last
    // block's 4 tokens run through the model
    let counters = SlotEngine::prefix_counters(&warm).unwrap();
    assert_eq!(counters.hit_tokens, 12);
    assert_eq!(counters.miss_tokens, 16 + 4);
    // decode must continue identically on the imported rows
    for tok in [9u32, 33, 57] {
        let x = cold.step_slot(0, tok).unwrap();
        let y = warm.step_slot(1, tok).unwrap();
        assert_eq!(x, y, "decode after full-prompt hit diverges");
    }
}

/// Eviction under budget pressure mid-decode: blocks pinned by an
/// in-flight request survive (the publish that can't fit is refused),
/// the pinned request decodes on unaffected, and once it resets the
/// pressure evicts its blocks LRU-first.
#[test]
fn budget_pressure_mid_decode_spares_pinned_blocks() {
    let cfg = tiny();
    let w = Weights::synthetic(&cfg, 71);
    let fdb = BTreeMap::new();
    let prompt_a: Vec<u32> = (0..8u32).collect(); // 2 blocks of 4
    let prompt_b: Vec<u32> = (40..48u32).collect(); // 2 different blocks

    // budget: exactly A's two published blocks
    let block_bytes = 2 * cfg.n_layers * 4 * cfg.d_model * 4; // (K+V) rows
    let pc = Arc::new(Mutex::new(PrefixCache::new(4, 2 * block_bytes)));
    let mut e = engine(&w, &fdb, 2).with_prefix_cache(pc.clone());

    // cold publish of A, then a warm re-admission pins A's blocks
    e.prefill_slot(0, &prompt_a).unwrap();
    e.reset_slot(0);
    e.prefill_slot(0, &prompt_a).unwrap();
    assert_eq!(SlotEngine::prefix_counters(&e).unwrap().hit_tokens, 4);

    // mid-decode of slot 0, B's publish hits the budget: A's *pinned*
    // first block survives (only its unpinned second block may evict),
    // and the part of B's chain that cannot fit is refused
    let mut cold = engine(&w, &fdb, 2);
    cold.prefill_slot(0, &prompt_a).unwrap();
    e.prefill_slot(1, &prompt_b).unwrap();
    {
        let mut g = pc.lock().unwrap();
        g.assert_invariants();
        assert!(g.used_bytes() <= 2 * block_bytes, "budget overshot");
        assert!(g.stats().rejected_inserts >= 1, "B's overflow publish should be refused");
        assert!(g.stats().evictions <= 1, "only the unpinned A leaf may evict");
        let probe: Vec<u32> = prompt_a.iter().copied().chain([88]).collect();
        let (pins, matched) = g.acquire(&probe);
        assert_eq!(matched, 4, "the pinned A block must survive the pressure");
        g.release(&pins);
    }
    for tok in [5u32, 60, 2] {
        let x = cold.step_slot(0, tok).unwrap();
        let y = e.step_slot(0, tok).unwrap();
        assert_eq!(x, y, "pinned request's decode disturbed by budget pressure");
    }

    // request A finishes: its pins release, and B's next publish evicts
    e.reset_slot(0);
    e.reset_slot(1);
    e.prefill_slot(1, &prompt_b).unwrap();
    let g = pc.lock().unwrap();
    assert!(g.stats().evictions >= 1, "unpinned LRU blocks evict under pressure");
    assert!(g.used_bytes() <= 2 * block_bytes);
    let counters = SlotEngine::prefix_counters(&e).unwrap();
    assert!(counters.evictions >= 1, "engine counters surface the evictions");
}

/// Two workers racing the same cold prefix on one shared cache: both
/// miss, both prefill, both publish — the cache stores the bytes once,
/// nobody deadlocks, and both decode the cold reference stream.
#[test]
fn racing_cold_prefix_is_stored_once_and_streams_match() {
    let cfg = tiny();
    let w = Weights::synthetic(&cfg, 73);
    let fdb = half_fdb(&cfg, &w);
    let prompt: Vec<u32> = (0..12u32).map(|i| (i * 3 + 1) % cfg.vocab as u32).collect();

    // cold reference stream
    let mut reference = Vec::new();
    {
        let mut cold = engine(&w, &fdb, 1);
        let mut logits = cold.prefill_slot(0, &prompt).unwrap();
        for _ in 0..5 {
            let tok = db_llm::coordinator::serve::argmax(&logits) as u32;
            reference.push(tok);
            logits = cold.step_slot(0, tok).unwrap();
        }
    }

    let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
    let barrier = Arc::new(Barrier::new(2));
    let mut handles = Vec::new();
    for t in 0..2 {
        let (pc, barrier) = (pc.clone(), barrier.clone());
        let (w, fdb, prompt) = (w.clone(), fdb.clone(), prompt.clone());
        handles.push(std::thread::spawn(move || {
            let mut e = NativeEngine::new(w, &fdb, tiny().seq_len, 42 + t)
                .with_slots(1)
                .with_prefix_cache(pc);
            barrier.wait(); // both prefill the same cold prefix at once
            let mut logits = e.prefill_slot(0, &prompt).unwrap();
            let mut stream = Vec::new();
            for _ in 0..5 {
                let tok = db_llm::coordinator::serve::argmax(&logits) as u32;
                stream.push(tok);
                logits = e.step_slot(0, tok).unwrap();
            }
            stream
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), reference, "racing stream diverges from cold");
    }
    let g = pc.lock().unwrap();
    g.assert_invariants();
    // 12 tokens / block 4 = 3 blocks (the chain may stop one short if
    // one racer matched the other's freshly published blocks), stored
    // exactly once
    assert!(g.entries() == 3 || g.entries() == 2, "entries: {}", g.entries());
    let per_block = 2 * cfg.n_layers * 4 * cfg.d_model * 4;
    assert_eq!(g.used_bytes(), g.entries() * per_block, "racing publish double-stored bytes");
}

/// A prompt longer than the attention window bypasses sharing (the
/// sliding-window truncation relabels positions) but still decodes
/// identically to a cold engine.
#[test]
fn over_window_prompts_bypass_the_cache() {
    let cfg = tiny();
    let w = Weights::synthetic(&cfg, 79);
    let fdb = BTreeMap::new();
    let long: Vec<u32> = (0..40u32).map(|i| i % cfg.vocab as u32).collect(); // > window 32

    let mut cold = engine(&w, &fdb, 1);
    let a = cold.prefill_slot(0, &long).unwrap();
    let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
    let mut warm = engine(&w, &fdb, 1).with_prefix_cache(pc.clone());
    let b = warm.prefill_slot(0, &long).unwrap();
    assert_eq!(a, b);
    assert_eq!(pc.lock().unwrap().entries(), 0, "over-window prompts must not publish");
    let counters = SlotEngine::prefix_counters(&warm).unwrap();
    assert_eq!(counters.hit_tokens, 0);
    assert_eq!(counters.miss_tokens, cfg.seq_len as u64, "bypass counts the window tokens");
}
