//! Failure injection: every loader/parser must reject corrupted inputs
//! with an error (never UB, never a wrong-answer success).

use db_llm::codec::{huffman, rle};
use db_llm::data::TokenStream;
use db_llm::model::Dbw;
use db_llm::runtime::{Manifest, Runtime};
use db_llm::util::{Json, Pcg32};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("dbllm_failures");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn truncated_dbw_rejected() {
    // write a valid file then chop it at every decile
    let mut tensors = std::collections::BTreeMap::new();
    tensors.insert("a".to_string(), (vec![8, 8], vec![1.0f32; 64]));
    let dbw = Dbw { config: Json::obj(vec![("k", Json::num(1.0))]), tensors };
    let p = tmp("trunc.dbw");
    dbw.save(&p).unwrap();
    let full = std::fs::read(&p).unwrap();
    for frac in 1..10 {
        let cut = full.len() * frac / 10;
        let p2 = tmp(&format!("trunc_{frac}.dbw"));
        std::fs::write(&p2, &full[..cut]).unwrap();
        assert!(Dbw::load(&p2).is_err(), "accepted {cut}/{} bytes", full.len());
    }
}

#[test]
fn bitflipped_dbw_header_rejected_or_consistent() {
    let mut tensors = std::collections::BTreeMap::new();
    tensors.insert("a".to_string(), (vec![4], vec![0.5f32; 4]));
    let dbw = Dbw { config: Json::Null, tensors };
    let p = tmp("flip.dbw");
    dbw.save(&p).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    // flip a byte inside the JSON header
    bytes[10] ^= 0xff;
    let p2 = tmp("flip2.dbw");
    std::fs::write(&p2, &bytes).unwrap();
    // must not panic; either parse error or a load that still validates
    let _ = Dbw::load(&p2);
}

#[test]
fn corrupt_manifest_fails_gracefully() {
    let p = tmp("manifest_bad.json");
    std::fs::write(&p, "{\"group_size\": }").unwrap();
    assert!(Manifest::load(&p).is_err());
    let p2 = tmp("manifest_empty.json");
    std::fs::write(&p2, "{}").unwrap();
    let m = Manifest::load(&p2).unwrap();
    assert!(m.teacher("S").is_err());
    assert!(m.sizes().is_err());
}

#[test]
fn runtime_open_on_missing_dir_errors() {
    assert!(Runtime::open("/nonexistent/artifacts_dir").is_err());
}

#[test]
fn runtime_rejects_garbage_hlo() {
    let dir = tmp("hlo_garbage");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"executables": {"bad": {"file": "bad.hlo.txt"}}, "sizes": {},
            "teachers": {}, "corpora": {}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "this is not HLO at all").unwrap();
    let mut rt = Runtime::open(&dir).unwrap();
    assert!(rt.executable("bad").is_err());
    assert!(rt.executable("missing_key").is_err());
}

#[test]
fn huffman_decoder_survives_fuzzed_blobs() {
    let mut rng = Pcg32::seeded(99);
    // random blobs: must error or return bytes, never panic
    for _ in 0..200 {
        let n = rng.range(0, 600);
        let blob: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let _ = huffman::decode(&blob);
    }
    // bit-flipped valid blobs
    let data: Vec<u8> = (0..500).map(|i| (i % 7) as u8).collect();
    let enc = huffman::encode(&data);
    for _ in 0..100 {
        let mut e = enc.clone();
        let i = rng.range(0, e.len());
        e[i] ^= 1 << rng.below(8);
        let _ = huffman::decode(&e); // may error or mis-decode, must not panic
    }
}

#[test]
fn rle_decoder_survives_fuzz() {
    let mut rng = Pcg32::seeded(100);
    for _ in 0..300 {
        let n = rng.range(0, 400);
        let blob: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let _ = rle::decode(&blob);
    }
}

#[test]
fn token_stream_rejects_odd_or_missing() {
    let p = tmp("odd.tok");
    std::fs::write(&p, [1u8, 2, 3]).unwrap();
    assert!(TokenStream::load(&p).is_err());
    assert!(TokenStream::load("/no/such/file.tok").is_err());
}

#[test]
fn json_parser_survives_fuzz() {
    let mut rng = Pcg32::seeded(101);
    let alphabet = b"{}[]\",:0123456789.eE+-truefalsn \\u00";
    for _ in 0..500 {
        let n = rng.range(0, 120);
        let s: String = (0..n)
            .map(|_| alphabet[rng.range(0, alphabet.len())] as char)
            .collect();
        let _ = Json::parse(&s); // must never panic
    }
}
