//! Failure injection: every loader/parser must reject corrupted inputs
//! with an error (never UB, never a wrong-answer success), and the
//! serving wire must degrade the same way — truncated JSON, binary
//! garbage, oversized lines, idle peers, and mid-request disconnects
//! get an error line or a clean close, never a panic or a hang.

use db_llm::codec::{huffman, rle};
use db_llm::data::TokenStream;
use db_llm::model::Dbw;
use db_llm::runtime::{Manifest, Runtime};
use db_llm::util::{Json, Pcg32};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("dbllm_failures");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn truncated_dbw_rejected() {
    // write a valid file then chop it at every decile
    let mut tensors = std::collections::BTreeMap::new();
    tensors.insert("a".to_string(), (vec![8, 8], vec![1.0f32; 64]));
    let dbw = Dbw { config: Json::obj(vec![("k", Json::num(1.0))]), tensors };
    let p = tmp("trunc.dbw");
    dbw.save(&p).unwrap();
    let full = std::fs::read(&p).unwrap();
    for frac in 1..10 {
        let cut = full.len() * frac / 10;
        let p2 = tmp(&format!("trunc_{frac}.dbw"));
        std::fs::write(&p2, &full[..cut]).unwrap();
        assert!(Dbw::load(&p2).is_err(), "accepted {cut}/{} bytes", full.len());
    }
}

#[test]
fn bitflipped_dbw_header_rejected_or_consistent() {
    let mut tensors = std::collections::BTreeMap::new();
    tensors.insert("a".to_string(), (vec![4], vec![0.5f32; 4]));
    let dbw = Dbw { config: Json::Null, tensors };
    let p = tmp("flip.dbw");
    dbw.save(&p).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    // flip a byte inside the JSON header
    bytes[10] ^= 0xff;
    let p2 = tmp("flip2.dbw");
    std::fs::write(&p2, &bytes).unwrap();
    // must not panic; either parse error or a load that still validates
    let _ = Dbw::load(&p2);
}

#[test]
fn corrupt_manifest_fails_gracefully() {
    let p = tmp("manifest_bad.json");
    std::fs::write(&p, "{\"group_size\": }").unwrap();
    assert!(Manifest::load(&p).is_err());
    let p2 = tmp("manifest_empty.json");
    std::fs::write(&p2, "{}").unwrap();
    let m = Manifest::load(&p2).unwrap();
    assert!(m.teacher("S").is_err());
    assert!(m.sizes().is_err());
}

#[test]
fn runtime_open_on_missing_dir_errors() {
    assert!(Runtime::open("/nonexistent/artifacts_dir").is_err());
}

#[test]
fn runtime_rejects_garbage_hlo() {
    let dir = tmp("hlo_garbage");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"executables": {"bad": {"file": "bad.hlo.txt"}}, "sizes": {},
            "teachers": {}, "corpora": {}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "this is not HLO at all").unwrap();
    let mut rt = Runtime::open(&dir).unwrap();
    assert!(rt.executable("bad").is_err());
    assert!(rt.executable("missing_key").is_err());
}

#[test]
fn huffman_decoder_survives_fuzzed_blobs() {
    let mut rng = Pcg32::seeded(99);
    // random blobs: must error or return bytes, never panic
    for _ in 0..200 {
        let n = rng.range(0, 600);
        let blob: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let _ = huffman::decode(&blob);
    }
    // bit-flipped valid blobs
    let data: Vec<u8> = (0..500).map(|i| (i % 7) as u8).collect();
    let enc = huffman::encode(&data);
    for _ in 0..100 {
        let mut e = enc.clone();
        let i = rng.range(0, e.len());
        e[i] ^= 1 << rng.below(8);
        let _ = huffman::decode(&e); // may error or mis-decode, must not panic
    }
}

#[test]
fn rle_decoder_survives_fuzz() {
    let mut rng = Pcg32::seeded(100);
    for _ in 0..300 {
        let n = rng.range(0, 400);
        let blob: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let _ = rle::decode(&blob);
    }
}

#[test]
fn token_stream_rejects_odd_or_missing() {
    let p = tmp("odd.tok");
    std::fs::write(&p, [1u8, 2, 3]).unwrap();
    assert!(TokenStream::load(&p).is_err());
    assert!(TokenStream::load("/no/such/file.tok").is_err());
}

#[test]
fn json_parser_survives_fuzz() {
    let mut rng = Pcg32::seeded(101);
    let alphabet = b"{}[]\",:0123456789.eE+-truefalsn \\u00";
    for _ in 0..500 {
        let n = rng.range(0, 120);
        let s: String = (0..n)
            .map(|_| alphabet[rng.range(0, alphabet.len())] as char)
            .collect();
        let _ = Json::parse(&s); // must never panic
    }
}

// ---------------------------------------------------------------------
// wire layer: the TCP server under hostile and half-dead clients
// ---------------------------------------------------------------------

use std::io::{BufRead, BufReader, Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use db_llm::coordinator::batcher::BatchPolicy;
use db_llm::coordinator::metrics::Metrics;
use db_llm::coordinator::serve::{serve_with, ConnConfig, DecodeParams, Generation, Generator};

/// Test double: echoes `prompt[0]` for exactly `max_tokens` steps.
struct EchoGen;

impl Generator for EchoGen {
    fn generate(
        &mut self,
        prompts: &[Vec<u32>],
        params: &[DecodeParams],
    ) -> anyhow::Result<Generation> {
        let outputs = prompts
            .iter()
            .zip(params)
            .map(|(p, d)| vec![p[0]; d.max_tokens])
            .collect::<Vec<_>>();
        let steps = params.iter().map(|d| d.max_tokens).max().unwrap_or(0);
        Ok(Generation { outputs, steps })
    }
}

/// Spin up a hardened server with the fake generator and return its
/// address plus the shared state the assertions need.
fn hardened_server() -> (std::net::SocketAddr, Arc<Metrics>, Arc<AtomicBool>) {
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let conn = ConnConfig {
        read_timeout: Some(Duration::from_millis(100)),
        write_timeout: Some(Duration::from_secs(5)),
        max_line_bytes: 4096,
        idle_timeout: Some(Duration::from_millis(400)),
    };
    let policy = BatchPolicy { max_batch: 4, linger: Duration::from_millis(2), ..Default::default() };
    let addr = serve_with(
        || Ok(EchoGen),
        "127.0.0.1:0",
        policy,
        1,
        metrics.clone(),
        running.clone(),
        conn,
    )
    .unwrap();
    (addr, metrics, running)
}

fn connect(addr: std::net::SocketAddr) -> std::net::TcpStream {
    loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) => std::thread::sleep(Duration::from_millis(50)),
        }
    }
}

/// Truncated JSON and raw binary garbage each get one error line back,
/// and the same connection keeps serving valid requests afterwards.
#[test]
fn wire_garbage_gets_error_lines_not_crashes() {
    let (addr, metrics, running) = hardened_server();
    let mut stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();

    // truncated JSON: the line arrives complete but doesn't parse
    writeln!(stream, "{{\"prompt\": [1, 2").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "truncated JSON got {line}");

    // binary garbage: not even UTF-8
    stream.write_all(&[0xff, 0xfe, 0x80, 0x01, b'\n']).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "binary garbage got {line}");

    // the connection survived both
    writeln!(stream, "{{\"prompt\": [5], \"max_tokens\": 3}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.usize_list("tokens").unwrap(), vec![5, 5, 5]);

    running.store(false, Ordering::Relaxed);
    // only the one valid request reached the workers; the garbage was
    // answered at the connection boundary without queueing anything
    assert_eq!(metrics.responses.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 0);
}

/// A request line over the byte cap gets a structured error and a
/// close — the server never buffers an unbounded line.
#[test]
fn wire_oversized_line_is_rejected_and_closed() {
    let (addr, metrics, running) = hardened_server();
    let mut stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    let huge = format!("{{\"prompt\": [{}1]}}\n", "1, ".repeat(4096));
    assert!(huge.len() > 4096, "test line must exceed the configured cap");
    // the server may slam the connection mid-upload; a write error here
    // is an acceptable outcome, not a test failure
    let _ = stream.write_all(huge.as_bytes());
    let mut line = String::new();
    let n = reader.read_line(&mut line).unwrap_or(0);
    if n > 0 {
        assert!(line.contains("error"), "oversized line got {line}");
        // next read must observe the close
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap_or(0), 0, "connection must close");
    }
    assert!(metrics.oversize_lines.load(Ordering::Relaxed) >= 1, "oversize uncounted");

    // the listener is unharmed
    let mut stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "{{\"prompt\": [3], \"max_tokens\": 2}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("tokens"), "server dead after oversize: {line}");
    running.store(false, Ordering::Relaxed);
}

/// Mid-request disconnects — a half-written line, or a vanished client
/// whose reply has nowhere to go — leave the server serving.
#[test]
fn wire_mid_request_disconnects_are_harmless() {
    let (addr, metrics, running) = hardened_server();

    {
        // half a request line, then gone
        let mut s = connect(addr);
        s.write_all(b"{\"prompt\": [9, 9").unwrap();
    }
    {
        // full request, but the client vanishes before the reply
        let mut s = connect(addr);
        writeln!(s, "{{\"prompt\": [9], \"max_tokens\": 2}}").unwrap();
    }

    let mut stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "{{\"prompt\": [4], \"max_tokens\": 2}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.usize_list("tokens").unwrap(), vec![4, 4]);
    running.store(false, Ordering::Relaxed);
    let _ = metrics; // counters vary with reply-write timing; liveness is the assertion
}

/// A peer that connects and then says nothing is reaped by the idle
/// timer instead of pinning a connection thread forever.
#[test]
fn wire_idle_connections_are_reaped() {
    let (addr, metrics, running) = hardened_server();
    let mut idle = connect(addr);
    // wait out the 400ms idle budget (100ms poll); generous for slow CI
    std::thread::sleep(Duration::from_millis(1500));
    let mut buf = [0u8; 8];
    // a reaped connection reads EOF (or a reset, depending on platform)
    match idle.read(&mut buf) {
        Ok(n) => assert_eq!(n, 0, "idle connection still open after the reap window"),
        Err(_) => {} // reset: also a close
    }
    assert!(metrics.conn_reaped.load(Ordering::Relaxed) >= 1, "reap uncounted");

    // reaping one peer doesn't touch the listener
    let mut stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "{{\"prompt\": [2], \"max_tokens\": 2}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("tokens"));
    running.store(false, Ordering::Relaxed);
}
