//! Paged KV block-pool property soaks: ref-count/COW protocol, racing
//! acquires, eviction under pinned pressure, and decoded publish-back
//! — the concurrency surface of the shared [`KvPool`].
//!
//! Everything here is artifact-free and deliberately thread-heavy with
//! *small* iteration counts: CI's ThreadSanitizer lane runs this file
//! as a named suite (`--test kv_pool`), so the goal is to exercise
//! every cross-thread edge (Arc clone/drop racing retire, the recycle
//! mutex, pinned-block reads racing a COW mutation, prefix-cache
//! publish/acquire/evict interleavings) rather than to grind.
//!
//! The single-threaded protocol tests live with the code
//! (`src/infer/kv.rs`, Miri-checked); the engine-level equivalence
//! gates live in `tests/prefix_cache.rs`.

use std::collections::BTreeMap;
use std::sync::{Arc, Barrier, Mutex};

use db_llm::coordinator::scheduler::SlotEngine;
use db_llm::coordinator::serve::argmax;
use db_llm::infer::{KvCache, KvPool, NativeEngine, PrefixCache};
use db_llm::model::{ModelConfig, Weights};

fn tiny() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 192,
        vocab: 96,
        seq_len: 32,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

/// Concurrent alloc/retire keeps the pool's books sound: counters are
/// audited *while* other threads allocate and drop, every thread sees
/// recycled storage, and the end state balances to zero live blocks.
#[test]
fn pool_accounting_sound_under_concurrent_alloc_retire() {
    let pool = Arc::new(KvPool::new(4, 2, 8, KvPool::UNBOUNDED));
    let barrier = Arc::new(Barrier::new(4));
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let pool = Arc::clone(&pool);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                for i in 0..200 {
                    let a = pool.alloc();
                    let b = pool.alloc();
                    drop(a);
                    if i % 16 == 0 {
                        // mid-churn audit: sound against racing threads
                        pool.assert_invariants();
                    }
                    drop(b);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let s = pool.stats();
    assert_eq!(s.live_blocks, 0, "every handle dropped");
    assert_eq!(s.retired, s.fresh_allocs + s.recycle_hits, "retire balances alloc");
    assert!(s.recycle_hits > 0, "churn must reuse retired storage");
    assert!(s.peak_blocks <= 8, "4 threads x 2 handles bounds the peak");
    pool.assert_invariants();
}

/// Racing acquires over one published prefix: every reader splices the
/// same shared handles into its own table, sees the publisher's exact
/// rows, and the pool's copy counters stay at zero — the zero-copy
/// guarantee holds under contention, not just single-threaded.
#[test]
fn racing_acquires_are_zero_copy() {
    let pool = Arc::new(KvPool::new(4, 2, 4, KvPool::UNBOUNDED));
    let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
    let prompt: Vec<u32> = (0..8u32).collect();

    // the "cold request": prefilled rows with position-derived values,
    // published as 2 full blocks
    let mut src = KvCache::new_in_pool(&pool, 32);
    for t in 0..8 {
        let s = src.advance();
        let row = [t as f32; 4];
        for l in 0..2 {
            src.write(l, s, &row, &row);
        }
    }
    pc.lock().unwrap().publish(&prompt, &src);
    assert_eq!(pc.lock().unwrap().entries(), 2);

    // lookups carry a suffix token: `acquire` never matches an entire
    // prompt (the model always runs >= 1 position), so a bare 8-token
    // lookup would deliberately stop at one block
    let lookup: Vec<u32> = prompt.iter().copied().chain([99]).collect();
    let barrier = Arc::new(Barrier::new(4));
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let pool = Arc::clone(&pool);
            let pc = Arc::clone(&pc);
            let lookup = lookup.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                for _ in 0..16 {
                    // lock only to walk + pin; the splice runs outside
                    let (pins, matched, blocks) = {
                        let mut g = pc.lock().unwrap();
                        let (pins, matched) = g.acquire(&lookup);
                        let blocks: Vec<_> =
                            pins.iter().map(|h| g.block(*h).expect("pinned")).collect();
                        (pins, matched, blocks)
                    };
                    assert_eq!(matched, 8, "full prefix short of nothing (8 = 2 blocks)");
                    let mut warm = KvCache::new_in_pool(&pool, 32);
                    for b in &blocks {
                        warm.append_shared(b);
                    }
                    assert_eq!(warm.len(), 8);
                    for i in 0..8 {
                        assert_eq!(warm.k_row(0, i)[0], i as f32, "imported row diverged");
                    }
                    warm.assert_invariants();
                    pc.lock().unwrap().release(&pins);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let s = pool.stats();
    assert_eq!(s.copied_rows, 0, "racing warm imports must copy zero K/V rows");
    assert_eq!(s.cow_copies, 0, "nobody mutated a shared block");
    pc.lock().unwrap().assert_invariants();
    pool.assert_invariants();
}

/// Copy-on-write isolates a pinned snapshot from the decoding slot:
/// reader threads hold the tail handle and re-read its rows while the
/// owner keeps appending — the pin's bytes never move (the owner wrote
/// into a private clone), which is exactly the no-data-race property
/// TSan checks here.
#[test]
fn cow_isolates_pinned_readers_from_decode() {
    let pool = Arc::new(KvPool::new(4, 1, 2, KvPool::UNBOUNDED));
    let mut c = KvCache::new_in_pool(&pool, 64);
    for t in 0..2 {
        let s = c.advance();
        let row = [t as f32, -(t as f32)];
        c.write(0, s, &row, &row);
    }
    let pinned = c.share_tail_for_audit().expect("tail exists");
    assert_eq!(pinned.len(), 2);

    let readers: Vec<_> = (0..2)
        .map(|_| {
            let pinned = Arc::clone(&pinned);
            std::thread::spawn(move || {
                for _ in 0..500 {
                    for i in 0..2 {
                        assert_eq!(pinned.k_row(0, i)[0], i as f32, "pinned snapshot moved");
                        assert_eq!(pinned.v_row(0, i)[1], -(i as f32));
                    }
                }
            })
        })
        .collect();
    // the owner decodes on, concurrently with the readers
    for t in 2..32 {
        let s = c.advance();
        let row = [100.0 + t as f32, 0.0];
        c.write(0, s, &row, &row);
    }
    for h in readers {
        h.join().unwrap();
    }
    let s = pool.stats();
    assert_eq!(s.cow_copies, 1, "first append into the pinned tail clones it once");
    assert_eq!(s.copied_rows, 2, "the clone carries the 2 pre-pin rows");
    assert_eq!(pinned.len(), 2, "the pin never grows");
    assert_eq!(c.len(), 32);
    c.assert_invariants();
}

/// Eviction under pinned pressure: a held chain survives arbitrary
/// publish pressure (pins are never victims), the cache never
/// overshoots its budget, and a slot that spliced a block *keeps its
/// rows* even after the cache entry is evicted — the `Arc` outlives
/// the eviction.
#[test]
fn eviction_under_pinned_pressure() {
    // 1 layer, width 2, 2-token blocks: 2*1*2*2*4 = 32 bytes per block
    let pool = Arc::new(KvPool::new(2, 1, 2, KvPool::UNBOUNDED));
    let block_bytes = pool.block_bytes();
    let pc = Arc::new(Mutex::new(PrefixCache::new(2, 4 * block_bytes)));

    let fill = |tokens: &[u32]| {
        let mut c = KvCache::new_in_pool(&pool, 32);
        for &t in tokens {
            let s = c.advance();
            let row = [t as f32, t as f32 + 0.5];
            c.write(0, s, &row, &row);
        }
        c
    };

    // chain A: 2 blocks, pinned for the whole soak
    let chain: Vec<u32> = vec![1, 2, 3, 4];
    pc.lock().unwrap().publish(&chain, &fill(&chain));
    let (pins, matched) = pc.lock().unwrap().acquire(&[1, 2, 3, 4, 9]);
    assert_eq!(matched, 4);

    // a transient reader splices chain A and immediately unpins: its
    // rows must survive even if the entries are later evicted
    let mut orphan = KvCache::new_in_pool(&pool, 32);
    let (p, blocks) = {
        let mut g = pc.lock().unwrap();
        let (p, m) = g.acquire(&[1, 2, 3, 4, 9]);
        assert_eq!(m, 4);
        let blocks: Vec<_> = p.iter().map(|h| g.block(*h).expect("pinned")).collect();
        (p, blocks)
    };
    for b in &blocks {
        orphan.append_shared(b);
    }
    pc.lock().unwrap().release(&p);
    drop(blocks);

    let barrier = Arc::new(Barrier::new(4));
    let handles: Vec<_> = (0..4u32)
        .map(|tid| {
            let pool = Arc::clone(&pool);
            let pc = Arc::clone(&pc);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                for it in 0..16u32 {
                    // distinct 2-token prefix per (thread, iteration):
                    // every publish lands a fresh block and squeezes
                    // the budget
                    let base = 1000 + tid * 100 + it * 2;
                    let tokens = vec![base, base + 1];
                    let mut c = KvCache::new_in_pool(&pool, 32);
                    for &t in &tokens {
                        let s = c.advance();
                        let row = [t as f32, 0.0];
                        c.write(0, s, &row, &row);
                    }
                    let mut g = pc.lock().unwrap();
                    g.publish(&tokens, &c);
                    g.assert_invariants();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let mut g = pc.lock().unwrap();
    assert!(g.used_bytes() <= 4 * block_bytes, "budget overshot under pressure");
    let (still, m) = g.acquire(&[1, 2, 3, 4, 9]);
    assert_eq!(m, 4, "pinned chain evicted under pressure");
    g.release(&still);
    g.release(&pins);
    g.assert_invariants();
    drop(g);

    // the orphan's spliced rows are intact regardless of what the LRU
    // did to the entries behind them
    assert_eq!(orphan.len(), 4);
    for (i, &t) in chain.iter().enumerate() {
        assert_eq!(orphan.k_row(0, i), &[t as f32, t as f32 + 0.5], "row {i} lost to eviction");
    }
    orphan.assert_invariants();
    pool.assert_invariants();
}

/// Racing engines over one shared prefix cache: both decode streams
/// stay bit-identical to a cold engine's, neither pool copies a K/V
/// row, and the decoded blocks published back at block boundaries warm
/// a third engine across prompt *and* reply — the multi-turn shape.
#[test]
fn racing_engines_stay_bit_identical_and_publish_back() {
    let cfg = tiny();
    let w = Weights::synthetic(&cfg, 77);
    let pc = Arc::new(Mutex::new(PrefixCache::new(4, 1 << 20)));
    let prompt: Vec<u32> = (0..4u32).collect();

    // cold reference stream (no sharing anywhere)
    let mut cold =
        NativeEngine::new(w.clone(), &BTreeMap::new(), cfg.seq_len, 42).with_slots(1);
    let mut logits = cold.prefill_slot(0, &prompt).unwrap();
    let mut expect = Vec::new();
    for _ in 0..4 {
        let t = argmax(&logits) as u32;
        expect.push(t);
        logits = cold.step_slot(0, t).unwrap();
    }

    let barrier = Arc::new(Barrier::new(2));
    let handles: Vec<_> = (0..2)
        .map(|_| {
            let pc = Arc::clone(&pc);
            let w = w.clone();
            let prompt = prompt.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut e = NativeEngine::new(w, &BTreeMap::new(), 32, 42)
                    .with_slots(1)
                    .with_prefix_cache(pc);
                barrier.wait();
                let mut logits = e.prefill_slot(0, &prompt).unwrap();
                let mut out = Vec::new();
                for _ in 0..4 {
                    let t = argmax(&logits) as u32;
                    out.push(t);
                    logits = e.step_slot(0, t).unwrap();
                }
                e.assert_invariants();
                (out, e.kv_pool().stats().copied_rows)
            })
        })
        .collect();
    for h in handles {
        let (out, copied) = h.join().unwrap();
        assert_eq!(out, expect, "shared-cache stream diverged from cold");
        assert_eq!(copied, 0, "warm or racing-cold prefill copied K/V rows");
    }

    // 4 prompt + 4 decoded tokens crossed the 4-token block boundary,
    // so both blocks are in the chain: turn 2 re-enters warm over the
    // decoded tokens too
    let turn2: Vec<u32> = prompt.iter().copied().chain(expect.iter().copied()).chain([20]).collect();
    let mut e2 = NativeEngine::new(w, &BTreeMap::new(), 32, 42)
        .with_slots(1)
        .with_prefix_cache(Arc::clone(&pc));
    e2.prefill_slot(0, &turn2).unwrap();
    let ctr = SlotEngine::prefix_counters(&e2).unwrap();
    assert_eq!(ctr.hit_tokens, 8, "prompt and decoded blocks both warm turn 2");
    assert_eq!(e2.kv_pool().stats().copied_rows, 0);
    e2.assert_invariants();
    pc.lock().unwrap().assert_invariants();
}
