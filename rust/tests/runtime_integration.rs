//! Integration: artifacts → PJRT runtime → evaluation, cross-checked
//! against both the python layer's reported metrics (manifest) and the
//! rust-native forward.  Requires `make artifacts`.

use db_llm::data::TokenStream;
use db_llm::eval::ppl;
use db_llm::model::native::Forward;
use db_llm::runtime::{session::load_teacher, Runtime, Session};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn skip_if_missing() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::open(dir).expect("open runtime"))
}

#[test]
fn manifest_teachers_and_executables_present() {
    let Some(rt) = skip_if_missing() else { return };
    let tags = rt.manifest.teacher_tags().unwrap();
    assert!(tags.len() >= 4, "expected >=4 teachers, got {tags:?}");
    for size in rt.manifest.sizes().unwrap() {
        for kind in ["fwd_logits", "fwd_nll", "fwd_fdb_nll", "dad_step"] {
            let f = rt.manifest.executable_file(&format!("{kind}_{size}")).unwrap();
            assert!(artifacts_dir().join(&f).exists(), "missing {f}");
        }
    }
}

#[test]
fn hlo_forward_matches_native_forward() {
    let Some(mut rt) = skip_if_missing() else { return };
    let weights = load_teacher(&rt, "S").unwrap();
    let session = Session::new(&rt, &weights).unwrap();
    let (b, t) = (session.logits_batch, session.seq_len);
    let vocab = session.vocab;

    // deterministic token batch
    let tokens: Vec<i32> = (0..b * t).map(|i| ((i * 37 + 11) % vocab) as i32).collect();
    let logits = session.logits(&mut rt, &tokens).unwrap();
    assert_eq!(logits.len(), b * t * vocab);

    // native forward on row 0
    let row0: Vec<u32> = tokens[..t].iter().map(|&x| x as u32).collect();
    let native = Forward::new(&weights).run(&row0);
    let mut max_err = 0.0f32;
    for pos in 0..t {
        for v in 0..vocab {
            let a = logits[(pos * vocab) + v];
            let b_ = native.at(pos, v);
            max_err = max_err.max((a - b_).abs());
        }
    }
    assert!(max_err < 2e-2, "XLA vs native logits max err {max_err}");
}

#[test]
fn teacher_ppl_matches_python_report() {
    let Some(mut rt) = skip_if_missing() else { return };
    let info = rt.manifest.teacher("S").unwrap();
    let weights = load_teacher(&rt, "S").unwrap();
    let session = Session::new(&rt, &weights).unwrap();
    let stream = TokenStream::load(
        artifacts_dir().join(rt.manifest.corpus_eval_file("wiki").unwrap()),
    )
    .unwrap();
    let ppl = ppl::perplexity(&mut rt, &session, &stream, 64).unwrap();
    // python evaluated on randomly-sampled windows; ours are sequential —
    // agreement within 15% validates the whole marshalling path
    let rel = (ppl - info.eval_ppl_wiki).abs() / info.eval_ppl_wiki;
    assert!(rel < 0.15, "rust ppl {ppl:.2} vs python {:.2}", info.eval_ppl_wiki);
}

#[test]
fn nll_executable_consistent_with_logits_executable() {
    let Some(mut rt) = skip_if_missing() else { return };
    let weights = load_teacher(&rt, "S").unwrap();
    let session = Session::new(&rt, &weights).unwrap();
    let t = session.seq_len;
    let vocab = session.vocab;

    let window: Vec<u32> = (0..t as u32 + 1).map(|i| (i * 13 + 5) % vocab as u32).collect();
    // nll path
    let packed: Vec<i32> = (0..session.nll_batch)
        .flat_map(|_| window.iter().map(|&x| x as i32))
        .collect();
    let nll = session.nll(&mut rt, &packed).unwrap();
    // logits path on the same inputs (first logits_batch rows)
    let inputs: Vec<i32> = (0..session.logits_batch)
        .flat_map(|_| window[..t].iter().map(|&x| x as i32))
        .collect();
    let logits = session.logits(&mut rt, &inputs).unwrap();
    for pos in 0..t {
        let row = &logits[pos * vocab..(pos + 1) * vocab];
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
        let z: f64 = row.iter().map(|&v| ((v as f64) - mx).exp()).sum();
        let expect = mx + z.ln() - row[window[pos + 1] as usize] as f64;
        let got = nll[pos] as f64;
        assert!((got - expect).abs() < 5e-3, "pos {pos}: {got} vs {expect}");
    }
}

#[test]
fn fdb_executable_runs_and_matches_dequant_session() {
    use db_llm::quant::{fdb::Fdb, Quantizer, Calib};
    let Some(mut rt) = skip_if_missing() else { return };
    let weights = load_teacher(&rt, "S").unwrap();

    // quantize with FDB, build both paths
    let key = "fwd_fdb_nll_S";
    let (frozen_names, quad_names) = rt.manifest.fdb_order(key).unwrap();
    let mut args: Vec<xla::Literal> = Vec::new();
    let mut fdb_layers = std::collections::BTreeMap::new();
    let empty = Calib::empty(0);
    let dequant = weights.map_linears(|name, w| {
        let q = Fdb { group: 64 }.quantize(w, &empty);
        fdb_layers.insert(name.to_string(), q.fdb.unwrap());
        q.w_hat
    });
    for name in &frozen_names {
        if let Some(m) = weights.mats.get(name) {
            args.push(db_llm::runtime::lit_f32(&m.data, &[m.rows as i64, m.cols as i64]).unwrap());
        } else {
            let v = &weights.vecs[name];
            args.push(db_llm::runtime::lit_f32(v, &[v.len() as i64]).unwrap());
        }
    }
    for name in &quad_names {
        let (lin, kind) = name.rsplit_once('.').unwrap();
        let layer = &fdb_layers[lin];
        let lit = match kind {
            "b1" => {
                let m = layer.b1.unpack();
                db_llm::runtime::lit_f32(&m.data, &[m.rows as i64, m.cols as i64]).unwrap()
            }
            "b2" => {
                let m = layer.b2.unpack();
                db_llm::runtime::lit_f32(&m.data, &[m.rows as i64, m.cols as i64]).unwrap()
            }
            "a1" => db_llm::runtime::lit_f32(
                &layer.a1.data,
                &[layer.a1.rows as i64, layer.a1.cols as i64],
            )
            .unwrap(),
            _ => db_llm::runtime::lit_f32(
                &layer.a2.data,
                &[layer.a2.rows as i64, layer.a2.cols as i64],
            )
            .unwrap(),
        };
        args.push(lit);
    }
    let session = Session::new(&rt, &dequant).unwrap();
    let (b, t) = (session.nll_batch, session.seq_len + 1);
    let vocab = session.vocab;
    let tokens: Vec<i32> = (0..b * t).map(|i| ((i * 29 + 3) % vocab) as i32).collect();
    args.push(db_llm::runtime::lit_i32(&tokens, &[b as i64, t as i64]).unwrap());

    // the Pallas-kernel path
    let out = rt.run(key, &args).unwrap();
    let nll_fdb = out[0].to_vec::<f32>().unwrap();
    // the dequantized-weights path through the plain executable
    let nll_deq = session.nll(&mut rt, &tokens).unwrap();
    assert_eq!(nll_fdb.len(), nll_deq.len());
    let mut max_err = 0.0f32;
    for (a, b_) in nll_fdb.iter().zip(&nll_deq) {
        max_err = max_err.max((a - b_).abs());
    }
    assert!(max_err < 5e-2, "pallas-FDB vs dequant nll max err {max_err}");
}
