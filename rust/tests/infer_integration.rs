//! Integration: the native KV-cached incremental engine vs the batched
//! native forward — the same cross-check pattern `runtime_integration`
//! uses for XLA vs native, applied to incremental vs full-recompute.
//! Everything here is artifact-free (synthetic weights) and runs in
//! every environment.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use db_llm::coordinator::batcher::BatchPolicy;
use db_llm::coordinator::metrics::Metrics;
use db_llm::coordinator::serve::{decode_batch, serve, DecodeParams, Generator};
use db_llm::infer::{IncrementalForward, KvCache, NativeEngine};
use db_llm::model::native::Forward;
use db_llm::model::{ModelConfig, Weights};
use db_llm::quant::FdbLinear;
use db_llm::util::{prop, Json, Pcg32};

fn tiny() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 192,
        vocab: 96,
        seq_len: 32,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

/// Property: prefill + incremental steps reproduce the batched
/// forward's last-position logits at *every* prefix, for random
/// sequences, random prefill split points and random weights.
#[test]
fn incremental_logits_match_full_forward() {
    let cfg = tiny();
    prop::check(8, |rng| {
        let weights = Weights::synthetic(&cfg, rng.next_u64());
        let len = rng.range(2, 13);
        let toks: Vec<u32> = (0..len).map(|_| rng.below(cfg.vocab as u32)).collect();
        let split = rng.range(1, len); // prefill [0, split), step the rest
        let mut f = IncrementalForward::new(weights.clone(), &BTreeMap::new());
        let mut cache = KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);

        let mut incremental = vec![f.prefill(&mut cache, &toks[..split])];
        for &t in &toks[split..] {
            incremental.push(f.step(&mut cache, t));
        }
        // incremental[i] is the next-token distribution after prefix
        // [0, split + i) — compare against the batched forward's last row
        for (i, inc) in incremental.iter().enumerate() {
            let prefix = &toks[..split + i];
            let full = Forward::new(&weights).run(prefix);
            let last = full.row(prefix.len() - 1);
            for (v, (a, b)) in inc.iter().zip(last).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                    "prefix {} vocab {v}: incremental {a} vs full {b}",
                    prefix.len()
                );
            }
        }
    });
}

/// The full-recompute reference: a `decode_batch` step function that
/// re-runs the batched native forward over every row's whole window —
/// exactly what the XLA decode loop does, minus the device.
fn full_recompute_step(
    weights: &Weights,
    b: usize,
    t: usize,
    vocab: usize,
) -> impl FnMut(&[i32]) -> anyhow::Result<Vec<f32>> + '_ {
    move |toks: &[i32]| {
        let mut out = vec![0.0f32; b * t * vocab];
        for r in 0..b {
            let row: Vec<u32> = toks[r * t..(r + 1) * t].iter().map(|&x| x as u32).collect();
            let logits = Forward::new(weights).run(&row);
            out[r * t * vocab..(r + 1) * t * vocab].copy_from_slice(&logits.data);
        }
        Ok(out)
    }
}

/// Acceptance: `NativeEngine` (prefill + N cached steps) emits the
/// *identical* greedy token stream as the full-recompute decode loop
/// (`decode_batch` over the batched native forward) on the same
/// weights, prompts and budgets — per row, including early stop.
#[test]
fn native_engine_matches_full_recompute_greedy() {
    let cfg = tiny();
    let weights = Weights::synthetic(&cfg, 17);
    let (b, t, vocab) = (2usize, 16usize, cfg.vocab);
    let prompts = vec![vec![5u32, 10, 15], vec![7u32]];
    let params = vec![DecodeParams::greedy(5), DecodeParams::greedy(3)];

    // full recompute: every step re-runs the whole window (O(T²) total)
    let mut rng = Pcg32::seeded(1);
    let step = full_recompute_step(&weights, b, t, vocab);
    let reference = decode_batch(step, b, t, vocab, &prompts, &params, &mut rng).unwrap();

    // KV-cached: prefill once, then one O(window) step per token
    let mut engine = NativeEngine::new(weights.clone(), &BTreeMap::new(), t, 42);
    let cached = engine.generate(&prompts, &params).unwrap();

    assert_eq!(cached.outputs, reference.outputs, "token streams must be identical");
    assert_eq!(cached.steps, reference.steps);

    // and with a stop token cut from the reference stream
    let stop = reference.outputs[0][1];
    let stopping = vec![
        DecodeParams { max_tokens: 5, temperature: 0.0, stop: Some(stop), speculate: true },
        DecodeParams::greedy(3),
    ];
    let mut rng = Pcg32::seeded(2);
    let step = full_recompute_step(&weights, b, t, vocab);
    let ref_stop = decode_batch(step, b, t, vocab, &prompts, &stopping, &mut rng).unwrap();
    let cached_stop = engine.generate(&prompts, &stopping).unwrap();
    assert_eq!(cached_stop.outputs, ref_stop.outputs);
    assert_eq!(cached_stop.outputs[0].last(), Some(&stop), "row 0 ends at its stop token");
}

/// The FDB execution form decodes the same distribution as the
/// dequantized dense weights — the paper's sparse kernel sits on the
/// decode path without changing the model.
#[test]
fn fdb_backed_incremental_matches_dequant_dense() {
    let cfg = tiny();
    let weights = Weights::synthetic(&cfg, 23);
    let mut fdb = BTreeMap::new();
    for name in cfg.linear_names() {
        fdb.insert(name.clone(), FdbLinear::from_weights(weights.mat(&name), 64));
    }
    let dequant = weights.map_linears(|name, _| fdb[name].dequant());

    let mut f_fdb = IncrementalForward::new(weights, &fdb);
    let mut f_dense = IncrementalForward::new(dequant, &BTreeMap::new());
    assert_eq!(f_fdb.n_fdb_ops(), cfg.linear_names().len());

    let mut c_fdb = KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);
    let mut c_dense = KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);
    let prompt = [3u32, 1, 4, 1, 5];
    let a = f_fdb.prefill(&mut c_fdb, &prompt);
    let b = f_dense.prefill(&mut c_dense, &prompt);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-2 * (1.0 + y.abs()), "prefill: {x} vs {y}");
    }
    for tok in [9u32, 2, 6] {
        let a = f_fdb.step(&mut c_fdb, tok);
        let b = f_dense.step(&mut c_dense, tok);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-2 * (1.0 + y.abs()), "step: {x} vs {y}");
        }
    }
}

/// The whole serving stack (TCP listener, batcher, worker pool,
/// metrics) runs unchanged on the native backend — and, unlike the XLA
/// path, needs no artifacts, so this exercises `serve()` end to end in
/// every environment.
#[test]
fn native_backend_serves_over_tcp() {
    let cfg = tiny();
    let metrics = Arc::new(Metrics::default());
    let running = Arc::new(AtomicBool::new(true));
    let factory_cfg = cfg.clone();
    let addr = serve(
        move || {
            let weights = Weights::synthetic(&factory_cfg, 31);
            Ok(NativeEngine::new(weights, &BTreeMap::new(), factory_cfg.seq_len, 5))
        },
        "127.0.0.1:0",
        BatchPolicy::default(),
        2,
        metrics.clone(),
        running.clone(),
    )
    .unwrap();

    let mut stream = loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    };
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // greedy requests are deterministic and honor their budget
    let mut responses = Vec::new();
    for _ in 0..2 {
        writeln!(stream, "{{\"prompt\": [5, 10, 15], \"max_tokens\": 6}}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        let toks = j.usize_list("tokens").unwrap();
        assert_eq!(toks.len(), 6);
        assert!(toks.iter().all(|&t| t < cfg.vocab));
        responses.push(toks);
    }
    assert_eq!(responses[0], responses[1], "greedy decode must be deterministic");

    // malformed lines still get an error reply, connection stays up
    writeln!(stream, "not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "got {line}");
    writeln!(stream, "{{\"prompt\": [1], \"max_tokens\": 2}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("tokens"), "got {line}");

    running.store(false, std::sync::atomic::Ordering::Relaxed);
    assert!(metrics.responses.load(std::sync::atomic::Ordering::Relaxed) >= 3);
}

/// Long generations slide the window: the engine must keep decoding
/// with bounded cache and stay deterministic.
#[test]
fn sliding_window_decode_is_deterministic() {
    let cfg = tiny();
    let window = 8;
    let prompts = vec![(0..6u32).collect::<Vec<_>>()];
    let params = vec![DecodeParams::greedy(12)]; // 6 + 12 >> window
    let mut e1 = NativeEngine::new(Weights::synthetic(&cfg, 29), &BTreeMap::new(), window, 1);
    let mut e2 = NativeEngine::new(Weights::synthetic(&cfg, 29), &BTreeMap::new(), window, 2);
    let a = e1.generate(&prompts, &params).unwrap();
    let b = e2.generate(&prompts, &params).unwrap();
    assert_eq!(a.outputs[0].len(), 12);
    assert_eq!(a.outputs, b.outputs, "greedy decode is seed-independent");
}
