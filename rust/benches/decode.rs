//! Decode scaling: the KV-cached incremental step vs full-window
//! recompute, at several window occupancies — the measured form of the
//! tentpole claim that a cached step is O(T) (roughly flat in sequence
//! position) while the recompute loop pays O(T²) per generated token.
//!
//!     cargo bench --bench decode        (BENCH_QUICK=1 for smoke)

use std::collections::BTreeMap;

use db_llm::infer::{IncrementalForward, KvCache};
use db_llm::model::native::Forward;
use db_llm::model::{ModelConfig, Weights};
use db_llm::quant::FdbLinear;
use db_llm::util::bench::{black_box, Bench};

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "bench".into(),
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 384,
        vocab: 256,
        seq_len: 128,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

fn main() {
    let cfg = cfg();
    let weights = Weights::synthetic(&cfg, 1);
    let mut b = Bench::new("decode");

    for &t in &[16usize, 32, 64, 128] {
        let toks: Vec<u32> = (0..t as u32).map(|i| i % cfg.vocab as u32).collect();

        // what the O(T²) loop pays per generated token at position t:
        // one full forward over the window
        b.bench_with_work(&format!("full_recompute_T{t}"), Some(t as f64), || {
            black_box(Forward::new(&weights).run(&toks));
        });

        // the KV-cached step at the same occupancy: the ring stays at
        // `t` entries, so every iteration measures a steady-state step
        let mut f = IncrementalForward::new(weights.clone(), &BTreeMap::new());
        let mut cache = KvCache::new(cfg.n_layers, t, cfg.d_model);
        f.prefill(&mut cache, &toks);
        b.bench_with_work(&format!("kv_step_T{t}"), Some(1.0), || {
            black_box(f.step(&mut cache, 7));
        });
    }

    // the same step with every linear on the compiled FDB sparse
    // kernel (the paper's decode path) at one representative window
    let mut fdb = BTreeMap::new();
    for name in cfg.linear_names() {
        fdb.insert(name.clone(), FdbLinear::from_weights(weights.mat(&name), 64));
    }
    let t = 64usize;
    let toks: Vec<u32> = (0..t as u32).collect();
    let mut f = IncrementalForward::new(weights.clone(), &fdb);
    let mut cache = KvCache::new(cfg.n_layers, t, cfg.d_model);
    f.prefill(&mut cache, &toks);
    b.bench_with_work(&format!("kv_step_fdb_T{t}"), Some(1.0), || {
        black_box(f.step(&mut cache, 7));
    });

    b.report();
}
