//! Decode scaling: the KV-cached incremental step vs full-window
//! recompute, at several window occupancies — the measured form of the
//! tentpole claim that a cached step is O(T) (roughly flat in sequence
//! position) while the recompute loop pays O(T²) per generated token.
//!
//! Plus the scheduler comparison: mixed-length traffic (lengths
//! {4, 32, 128} interleaved) drained by the continuous-batching
//! scheduler vs static arrival-order waves.  Deterministic lockstep
//! metrics (decode ticks, stalled row-steps — what a batch-synchronous
//! device pays) and measured wall clock both land in
//! `BENCH_scheduler.json` at the repo root.
//!
//!     cargo bench --bench decode        (BENCH_QUICK=1 for smoke)

use std::collections::BTreeMap;

use db_llm::coordinator::scheduler::{Job, ManualClock, Scheduler, SchedulerConfig};
use db_llm::coordinator::serve::{DecodeParams, Generator};
use db_llm::infer::{IncrementalForward, KvCache, NativeEngine};
use db_llm::model::native::Forward;
use db_llm::model::{ModelConfig, Weights};
use db_llm::quant::FdbLinear;
use db_llm::util::bench::{black_box, Bench};
use db_llm::util::Json;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "bench".into(),
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 384,
        vocab: 256,
        seq_len: 128,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

fn main() {
    let cfg = cfg();
    let weights = Weights::synthetic(&cfg, 1);
    let mut b = Bench::new("decode");

    for &t in &[16usize, 32, 64, 128] {
        let toks: Vec<u32> = (0..t as u32).map(|i| i % cfg.vocab as u32).collect();

        // what the O(T²) loop pays per generated token at position t:
        // one full forward over the window
        b.bench_with_work(&format!("full_recompute_T{t}"), Some(t as f64), || {
            black_box(Forward::new(&weights).run(&toks));
        });

        // the KV-cached step at the same occupancy: the ring stays at
        // `t` entries, so every iteration measures a steady-state step
        let mut f = IncrementalForward::new(weights.clone(), &BTreeMap::new());
        let mut cache = KvCache::new(cfg.n_layers, t, cfg.d_model);
        f.prefill(&mut cache, &toks);
        b.bench_with_work(&format!("kv_step_T{t}"), Some(1.0), || {
            black_box(f.step(&mut cache, 7));
        });
    }

    // the same step with every linear on the compiled FDB sparse
    // kernel (the paper's decode path) at one representative window
    let mut fdb = BTreeMap::new();
    for name in cfg.linear_names() {
        fdb.insert(name.clone(), FdbLinear::from_weights(weights.mat(&name), 64));
    }
    let t = 64usize;
    let toks: Vec<u32> = (0..t as u32).collect();
    let mut f = IncrementalForward::new(weights.clone(), &fdb);
    let mut cache = KvCache::new(cfg.n_layers, t, cfg.d_model);
    f.prefill(&mut cache, &toks);
    b.bench_with_work(&format!("kv_step_fdb_T{t}"), Some(1.0), || {
        black_box(f.step(&mut cache, 7));
    });

    bench_scheduler_mixed(&cfg, &weights, &mut b);

    b.report();
}

/// Mixed-length continuous-vs-static comparison: 12 requests with
/// budgets {4, 32, 128} interleaved in arrival order, 4 slots.
///
/// Two cost axes:
/// - **lockstep ticks** — what a batch-synchronous device pays: the
///   static batcher runs each arrival-order wave until its *slowest*
///   row finishes (finished rows stall in their slots), while the
///   continuous scheduler refills freed slots mid-flight.  These
///   counts are deterministic.
/// - **wall clock** — this host's CPU decode, where per-row work is
///   sequential either way, so the times mostly confirm the scheduler
///   adds no overhead.
fn bench_scheduler_mixed(cfg: &ModelConfig, weights: &Weights, b: &mut Bench) {
    const SLOTS: usize = 4;
    let budgets: Vec<usize> = [4usize, 32, 128].iter().copied().cycle().take(12).collect();
    let window = cfg.seq_len;
    let prompts: Vec<Vec<u32>> =
        (0..budgets.len()).map(|i| vec![(i % cfg.vocab) as u32, 3, 5]).collect();
    let params: Vec<DecodeParams> =
        budgets.iter().map(|&n| DecodeParams::greedy(n)).collect();
    let tokens: usize = budgets.iter().sum();

    // deterministic lockstep metrics for the static waves
    let mut ticks_static = 0usize;
    let mut stalled_static = 0usize;
    for wave in budgets.chunks(SLOTS) {
        let longest = wave.iter().copied().max().unwrap_or(0);
        ticks_static += longest;
        stalled_static += wave.iter().map(|&n| longest - n).sum::<usize>();
    }

    // one cold continuous drain for its deterministic tick metrics
    let engine = NativeEngine::new(weights.clone(), &BTreeMap::new(), window, 42)
        .with_slots(SLOTS);
    let sched_cfg = SchedulerConfig { slots: SLOTS, ..Default::default() };
    let mut sched = Scheduler::new(engine, ManualClock::default(), sched_cfg);
    let drain = |sched: &mut Scheduler<NativeEngine, ManualClock>| {
        for (p, d) in prompts.iter().zip(&params) {
            let job = Job { prompt: p.clone(), params: *d, timeout_ms: None, queued_for_ms: 0 };
            sched.submit(job);
        }
        let mut replies = 0usize;
        while !sched.is_idle() {
            replies += sched.tick().len();
        }
        assert_eq!(replies, prompts.len(), "every request answered exactly once");
    };
    drain(&mut sched);
    let ticks_continuous = sched.stats.ticks as usize;
    let busy = sched.stats.busy_slot_ticks as usize;
    assert_eq!(busy, tokens, "continuous slots never stall: busy ticks == tokens");

    // measured wall clock, same work each iteration
    let wall_cont =
        b.bench_with_work("continuous_mixed_4_32_128", Some(tokens as f64), || {
            drain(&mut sched);
        });
    let mut static_engine = NativeEngine::new(weights.clone(), &BTreeMap::new(), window, 42);
    let wall_static =
        b.bench_with_work("static_waves_mixed_4_32_128", Some(tokens as f64), || {
            for w in 0..prompts.len().div_ceil(SLOTS) {
                let lo = w * SLOTS;
                let hi = (lo + SLOTS).min(prompts.len());
                black_box(static_engine.generate(&prompts[lo..hi], &params[lo..hi]).unwrap());
            }
        });

    let out = Json::obj(vec![
        ("bench", Json::str("scheduler_mixed_lengths")),
        ("slots", Json::num(SLOTS as f64)),
        ("requests", Json::num(budgets.len() as f64)),
        ("lengths_cycle", Json::Arr(vec![Json::num(4.0), Json::num(32.0), Json::num(128.0)])),
        ("tokens", Json::num(tokens as f64)),
        ("ticks_static", Json::num(ticks_static as f64)),
        ("ticks_continuous", Json::num(ticks_continuous as f64)),
        ("stalled_row_steps_static", Json::num(stalled_static as f64)),
        ("stalled_row_steps_continuous", Json::num(0.0)),
        (
            "lockstep_speedup",
            Json::num(ticks_static as f64 / ticks_continuous.max(1) as f64),
        ),
        (
            "slot_occupancy_continuous",
            Json::num(busy as f64 / (ticks_continuous.max(1) * SLOTS) as f64),
        ),
        ("wall_ns_per_drain_continuous", Json::num(wall_cont)),
        ("wall_ns_per_drain_static", Json::num(wall_static)),
        ("wall_tokens_per_sec_continuous", Json::num(tokens as f64 * 1e9 / wall_cont)),
        ("wall_tokens_per_sec_static", Json::num(tokens as f64 * 1e9 / wall_static)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_scheduler.json");
    match std::fs::write(&path, format!("{out}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
