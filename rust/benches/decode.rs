//! Decode scaling: the KV-cached incremental step vs full-window
//! recompute, at several window occupancies — the measured form of the
//! tentpole claim that a cached step is O(T) (roughly flat in sequence
//! position) while the recompute loop pays O(T²) per generated token.
//!
//! Plus the scheduler comparison: mixed-length traffic (lengths
//! {4, 32, 128} interleaved) drained by the continuous-batching
//! scheduler vs static arrival-order waves.  Deterministic lockstep
//! metrics (decode ticks, stalled row-steps — what a batch-synchronous
//! device pays) and measured wall clock both land in
//! `BENCH_scheduler.json` at the repo root.
//!
//!     cargo bench --bench decode        (BENCH_QUICK=1 for smoke)

use std::collections::BTreeMap;

use db_llm::coordinator::scheduler::{
    Job, ManualClock, Scheduler, SchedulerConfig, SlotEngine, WallClock,
};
use db_llm::coordinator::serve::{argmax, DecodeParams, Generator};
use db_llm::infer::{IncrementalForward, KvCache, NativeEngine, SpecDecoder};
use db_llm::model::native::Forward;
use db_llm::model::{ModelConfig, Weights};
use db_llm::quant::FdbLinear;
use db_llm::util::bench::{black_box, Bench};
use db_llm::util::Json;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "bench".into(),
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 384,
        vocab: 256,
        seq_len: 128,
        rope_theta: 10000.0,
        rmsnorm_eps: 1e-5,
    }
}

fn main() {
    let cfg = cfg();
    let weights = Weights::synthetic(&cfg, 1);
    let mut b = Bench::new("decode");

    for &t in &[16usize, 32, 64, 128] {
        let toks: Vec<u32> = (0..t as u32).map(|i| i % cfg.vocab as u32).collect();

        // what the O(T²) loop pays per generated token at position t:
        // one full forward over the window
        b.bench_with_work(&format!("full_recompute_T{t}"), Some(t as f64), || {
            black_box(Forward::new(&weights).run(&toks));
        });

        // the KV-cached step at the same occupancy: the ring stays at
        // `t` entries, so every iteration measures a steady-state step
        let mut f = IncrementalForward::new(weights.clone(), &BTreeMap::new());
        let mut cache = KvCache::new(cfg.n_layers, t, cfg.d_model);
        f.prefill(&mut cache, &toks);
        b.bench_with_work(&format!("kv_step_T{t}"), Some(1.0), || {
            black_box(f.step(&mut cache, 7));
        });
    }

    // the same step with every linear on the compiled FDB sparse
    // kernel (the paper's decode path) at one representative window
    let mut fdb = BTreeMap::new();
    for name in cfg.linear_names() {
        fdb.insert(name.clone(), FdbLinear::from_weights(weights.mat(&name), 64));
    }
    let t = 64usize;
    let toks: Vec<u32> = (0..t as u32).collect();
    let mut f = IncrementalForward::new(weights.clone(), &fdb);
    let mut cache = KvCache::new(cfg.n_layers, t, cfg.d_model);
    f.prefill(&mut cache, &toks);
    b.bench_with_work(&format!("kv_step_fdb_T{t}"), Some(1.0), || {
        black_box(f.step(&mut cache, 7));
    });

    bench_scheduler_mixed(&cfg, &weights, &mut b);
    bench_fused_step(&cfg, &weights, &mut b);
    bench_prefix_cache(&cfg, &weights, &mut b);
    bench_kv_pool(&cfg, &weights, &mut b);
    bench_serving_trace(&cfg, &weights, &mut b);
    bench_spec_decode(&cfg, &weights, &mut b);

    b.report();
}

/// Observability bench: drain 24 mixed requests through the continuous
/// scheduler on the wall clock with tracing on and every tick profiled
/// (`profile_every: 1`), then dump the phase-timed latency distribution
/// — TTFT / inter-token / queue-wait / prefill percentiles straight
/// from the scheduler's `SchedHists`, plus the engine phase timers —
/// into `BENCH_serving_trace.json`.  The drain itself is also timed so
/// the committed numbers pin the *with-tracing* cost; the isolation
/// suite (tests/observability.rs) pins that tracing never changes the
/// decoded streams.
fn bench_serving_trace(cfg: &ModelConfig, weights: &Weights, b: &mut Bench) {
    const SLOTS: usize = 4;
    const REQUESTS: usize = 24;
    const DECODE: usize = 8;
    const PROMPT: usize = 12;
    let window = cfg.seq_len;
    let engine =
        NativeEngine::new(weights.clone(), &BTreeMap::new(), window, 42).with_slots(SLOTS);
    let sched_cfg =
        SchedulerConfig { slots: SLOTS, trace: true, profile_every: 1, ..Default::default() };
    let mut sched = Scheduler::new(engine, WallClock::default(), sched_cfg);
    let prompts: Vec<Vec<u32>> = (0..REQUESTS as u32)
        .map(|r| (0..PROMPT as u32).map(|t| (t * 3 + r * 11) % cfg.vocab as u32).collect())
        .collect();
    let tokens = REQUESTS * DECODE;
    let ns_drain = b.bench_with_work("serving_trace_drain", Some(tokens as f64), || {
        for p in &prompts {
            sched.submit(Job {
                prompt: p.clone(),
                params: DecodeParams::greedy(DECODE),
                timeout_ms: None,
                queued_for_ms: 0,
            });
        }
        let mut replies = 0usize;
        while !sched.is_idle() {
            replies += sched.tick().len();
        }
        assert_eq!(replies, REQUESTS, "every request answered exactly once");
    });
    let h = sched.hists;
    let s = sched.stats;
    let trace_events = sched.spans().len();
    let out = Json::obj(vec![
        ("bench", Json::str("serving_trace")),
        ("model", Json::str(cfg.name.clone())),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("window", Json::num(window as f64)),
        ("slots", Json::num(SLOTS as f64)),
        ("requests", Json::num(REQUESTS as f64)),
        ("decode_tokens", Json::num(DECODE as f64)),
        ("ttft_p50_us", Json::num(h.ttft_us.percentile(0.50) as f64)),
        ("ttft_p95_us", Json::num(h.ttft_us.percentile(0.95) as f64)),
        ("ttft_p99_us", Json::num(h.ttft_us.percentile(0.99) as f64)),
        ("itl_p50_us", Json::num(h.itl_us.percentile(0.50) as f64)),
        ("itl_p95_us", Json::num(h.itl_us.percentile(0.95) as f64)),
        ("itl_p99_us", Json::num(h.itl_us.percentile(0.99) as f64)),
        ("queue_wait_p50_us", Json::num(h.queue_wait_us.percentile(0.50) as f64)),
        ("prefill_p50_us", Json::num(h.prefill_us.percentile(0.50) as f64)),
        ("wall_ns_per_token_decode", Json::num(ns_drain / tokens as f64)),
        (
            "wall_ns_per_prefill",
            Json::num(s.engine_prefill_ns as f64 / s.engine_prefill_calls.max(1) as f64),
        ),
        ("trace_events", Json::num(trace_events as f64)),
        ("trace_dropped", Json::num(s.trace_dropped as f64)),
        ("profiled_ticks", Json::num(s.profiled_ticks as f64)),
        (
            "note",
            // byte-identical to the committed BENCH_serving_trace.json
            // note, so a bench run only churns the measured fields
            Json::str(
                "latency percentiles come from the scheduler's log2-bucketed SchedHists \
                 (bucket geometric mean, so p50 is within sqrt(2) of the true value) with \
                 tracing on and every tick profiled; all latency and wall_* fields are \
                 host-dependent and filled in by `cargo bench --bench decode`, which \
                 overwrites this file; tracing never changes the decoded streams \
                 (tests/observability.rs pins bit-identical fused-vs-sequential output \
                 with tracing enabled)",
            ),
        ),
    ]);
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serving_trace.json");
    match std::fs::write(&path, format!("{out}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

/// Shared-prefix prefill sweep: 8 requests whose 64-token prompts share
/// a prefix of {0%, 50%, 75%, 100%} of their length, prefilled cold
/// (no sharing) vs through a `PrefixCache` (16-token blocks).  The
/// deterministic cost model is **prefill token-work**: cold pays every
/// prompt token every time; warm pays only the uncached suffix (a
/// full-prompt match holds its last block back, so 100% overlap prefills
/// one block).  The model is asserted against the engine's hit/miss
/// counters, then wall clock per drain is measured both ways.  Results
/// land in `BENCH_prefix_cache.json`; warm and cold prefills emit
/// bit-identical logits (tests/prefix_cache.rs pins this).
fn bench_prefix_cache(cfg: &ModelConfig, weights: &Weights, b: &mut Bench) {
    use db_llm::infer::PrefixCache;
    use std::sync::{Arc, Mutex};
    const REQUESTS: usize = 8;
    const PROMPT: usize = 64;
    const BLOCK: usize = 16;
    let window = cfg.seq_len;
    let none = BTreeMap::new();
    // shared prefix of `plen` tokens + per-request suffix; `drain`
    // varies the suffix so later drains model *new* requests arriving
    // with the same shared prefix (identical prompts at 100% overlap)
    let vocab = cfg.vocab as u32;
    let prompt_for = move |plen: usize, r: u32, drain: u32| -> Vec<u32> {
        let mut p: Vec<u32> = (0..plen as u32).map(|i| (i * 5) % vocab).collect();
        p.extend(
            (plen as u32..PROMPT as u32).map(|i| (i * 7 + r * 13 + drain * 29 + 1) % vocab),
        );
        p
    };
    let mut sweep = Vec::new();
    for &(frac, plen) in &[(0.0f64, 0usize), (0.5, 32), (0.75, 48), (1.0, 64)] {
        // a full-prompt match holds its last block back (the model must
        // run ≥ 1 suffix token for the logits)
        let matched = if plen == PROMPT { plen - BLOCK } else { plen };
        let cold_tokens = REQUESTS * PROMPT;
        let steady_tokens = REQUESTS * (PROMPT - matched);

        let mut cold = NativeEngine::new(weights.clone(), &none, window, 42).with_slots(1);
        let mut drain = 0u32;
        let ns_cold = b.bench_with_work(
            &format!("prefill_cold_overlap{}", (frac * 100.0) as u32),
            Some(cold_tokens as f64),
            || {
                drain += 1;
                for r in 0..REQUESTS as u32 {
                    let p = prompt_for(plen, r, drain);
                    black_box(cold.prefill_slot(0, &p).unwrap());
                    cold.reset_slot(0);
                }
            },
        );

        let pc = Arc::new(Mutex::new(PrefixCache::new(BLOCK, 64 << 20)));
        let mut warm = NativeEngine::new(weights.clone(), &none, window, 42)
            .with_slots(1)
            .with_prefix_cache(pc);
        // drain 0: request 0 is the cold publisher of the shared
        // prefix, requests 1..R hit it — the deterministic model the
        // committed numbers record, asserted against the counters
        for r in 0..REQUESTS as u32 {
            let p = prompt_for(plen, r, 0);
            warm.prefill_slot(0, &p).unwrap();
            warm.reset_slot(0);
        }
        let first = SlotEngine::prefix_counters(&warm).unwrap();
        let first_drain_tokens = PROMPT + (REQUESTS - 1) * (PROMPT - matched);
        assert_eq!(
            first.miss_tokens as usize, first_drain_tokens,
            "deterministic token-work model diverged (first drain, overlap {frac})"
        );
        // steady drains: fresh suffixes, shared prefix resident
        let mut wdrain = 0u32;
        let ns_warm = b.bench_with_work(
            &format!("prefill_warm_overlap{}", (frac * 100.0) as u32),
            Some(steady_tokens.max(1) as f64),
            || {
                wdrain += 1;
                for r in 0..REQUESTS as u32 {
                    let p = prompt_for(plen, r, wdrain);
                    black_box(warm.prefill_slot(0, &p).unwrap());
                    warm.reset_slot(0);
                }
            },
        );

        sweep.push(Json::obj(vec![
            ("overlap", Json::num(frac)),
            ("shared_prefix_tokens", Json::num(plen as f64)),
            ("prompt_tokens", Json::num(PROMPT as f64)),
            ("requests", Json::num(REQUESTS as f64)),
            ("prefill_tokens_cold", Json::num(cold_tokens as f64)),
            ("prefill_tokens_warm_first_drain", Json::num(first_drain_tokens as f64)),
            ("prefill_tokens_warm_steady", Json::num(steady_tokens as f64)),
            (
                "token_work_reduction_steady",
                Json::num(1.0 - steady_tokens as f64 / cold_tokens as f64),
            ),
            // bench_with_work's mean is ns per iteration, and one
            // iteration is one full 8-request drain
            ("wall_ns_per_drain_cold", Json::num(ns_cold)),
            ("wall_ns_per_drain_warm", Json::num(ns_warm)),
            ("wall_prefill_speedup", Json::num(ns_cold / ns_warm)),
        ]));
    }
    let out = Json::obj(vec![
        ("bench", Json::str("prefix_cache_shared_prefill")),
        ("model", Json::str(cfg.name.clone())),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("window", Json::num(window as f64)),
        ("block_tokens", Json::num(BLOCK as f64)),
        ("sweep", Json::Arr(sweep)),
        (
            "note",
            // byte-identical to the committed BENCH_prefix_cache.json
            // note, so a bench run only churns the measured fields
            Json::str(
                "the token-work model is deterministic: cold prefill pays every prompt \
                 token per request, warm pays only the uncached suffix (block-granular; \
                 a 100% overlap match holds its last block back so the model always runs \
                 one block), asserted against the engine's prefix hit/miss counters; \
                 warm and cold prefill emit bit-identical logits \
                 (tests/prefix_cache.rs); wall_* fields are host-dependent and filled \
                 in by `cargo bench --bench decode`, which overwrites this file",
            ),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_prefix_cache.json");
    match std::fs::write(&path, format!("{out}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

/// Paged KV block-pool residency and copy-on-hit comparison.
///
/// Two deterministic cost axes, both asserted against the pool's own
/// counters, plus the measured warm-vs-cold prefill wall clock:
/// - **residency** — the old per-slot design reserved a full decode
///   window of K/V per request, so a byte budget admits
///   `budget / (2·n_layers·window·d_model·4)` requests no matter how
///   short their prompts.  The paged pool pins only
///   `ceil(prompt/block_tokens)` blocks plus one decode tail block, so
///   the same budget admits strictly more concurrent requests — here
///   measured by `can_admit`-gated prefills until the gate closes.
/// - **copy bytes on a warm hit** — the pre-pool prefix cache memcpy'd
///   every hit position's K/V rows into the slot ring; the paged cache
///   splices shared block handles, so the pool's `copied_rows` counter
///   stays at zero across the whole warm drain.
fn bench_kv_pool(cfg: &ModelConfig, weights: &Weights, b: &mut Bench) {
    use db_llm::infer::PrefixCache;
    use std::sync::{Arc, Mutex};
    const BLOCK: usize = 16;
    const SHORT_PROMPT: usize = 24;
    const WARM_PROMPT: usize = 64;
    const SLOTS_MAX: usize = 32;
    let window = cfg.seq_len;
    let none = BTreeMap::new();
    let vocab = cfg.vocab as u32;

    // geometry: bytes per cached position, per block, and per slot in
    // the old full-window-reservation design
    let row_bytes = 2 * cfg.n_layers * cfg.d_model * 4;
    let block_bytes = BLOCK * row_bytes;
    let worst_case_bytes = window * row_bytes;
    // a budget that fits exactly four worst-case slots
    let budget_bytes = 4 * worst_case_bytes;
    let resident_worst = budget_bytes / worst_case_bytes;

    // measured residency: admit short-prompt prefills through the
    // can_admit gate until the pool refuses to reserve another
    // worst-case prompt (its blocks plus one decode tail block)
    let mut gated = NativeEngine::new(weights.clone(), &none, window, 42)
        .with_slots(SLOTS_MAX)
        .with_kv_pool_bytes(budget_bytes);
    let mut resident_paged = 0usize;
    for slot in 0..SLOTS_MAX {
        if !gated.can_admit(SHORT_PROMPT) {
            break;
        }
        let p: Vec<u32> =
            (0..SHORT_PROMPT as u32).map(|i| (i * 3 + slot as u32 * 7) % vocab).collect();
        gated.prefill_slot(slot, &p).unwrap();
        resident_paged += 1;
    }
    assert!(
        resident_paged > resident_worst,
        "paged pool must admit strictly more requests ({resident_paged}) than the \
         per-slot worst case ({resident_worst}) under the same byte budget"
    );

    // copy bytes on a warm hit: publisher prefill, then an identical
    // prompt that matches all but its held-back last block
    let warm_prompt: Vec<u32> = (0..WARM_PROMPT as u32).map(|i| (i * 5) % vocab).collect();
    let pc = Arc::new(Mutex::new(PrefixCache::new(BLOCK, 64 << 20)));
    let mut warm = NativeEngine::new(weights.clone(), &none, window, 42)
        .with_slots(1)
        .with_prefix_cache(pc);
    warm.prefill_slot(0, &warm_prompt).unwrap();
    warm.reset_slot(0);
    warm.prefill_slot(0, &warm_prompt).unwrap();
    warm.reset_slot(0);
    let hit_tokens = SlotEngine::prefix_counters(&warm).unwrap().hit_tokens as usize;
    assert_eq!(
        hit_tokens,
        WARM_PROMPT - BLOCK,
        "a full-prompt match holds its last block back"
    );
    let ns_warm = b.bench_with_work("kv_pool_warm_prefill", Some(1.0), || {
        black_box(warm.prefill_slot(0, &warm_prompt).unwrap());
        warm.reset_slot(0);
    });
    let warm_copied_rows = warm.kv_pool().stats().copied_rows;
    assert_eq!(warm_copied_rows, 0, "warm prefix hits must copy zero K/V rows");

    let mut cold = NativeEngine::new(weights.clone(), &none, window, 42).with_slots(1);
    let ns_cold = b.bench_with_work("kv_pool_cold_prefill", Some(1.0), || {
        black_box(cold.prefill_slot(0, &warm_prompt).unwrap());
        cold.reset_slot(0);
    });

    let out = Json::obj(vec![
        ("bench", Json::str("kv_pool")),
        ("model", Json::str(cfg.name.clone())),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("window", Json::num(window as f64)),
        ("block_tokens", Json::num(BLOCK as f64)),
        ("budget_bytes", Json::num(budget_bytes as f64)),
        ("block_bytes", Json::num(block_bytes as f64)),
        ("worst_case_bytes_per_slot", Json::num(worst_case_bytes as f64)),
        ("requests_resident_worst_case", Json::num(resident_worst as f64)),
        ("requests_resident_paged", Json::num(resident_paged as f64)),
        ("hit_tokens", Json::num(hit_tokens as f64)),
        ("warm_copy_bytes_worst_case", Json::num((hit_tokens * row_bytes) as f64)),
        ("warm_copy_bytes_paged", Json::num((warm_copied_rows * row_bytes) as f64)),
        ("wall_ns_per_warm_prefill", Json::num(ns_warm)),
        ("wall_ns_per_cold_prefill", Json::num(ns_cold)),
        (
            "note",
            // byte-identical to the committed BENCH_kv_pool.json note,
            // so a bench run only churns the measured fields
            Json::str(
                "residency and copy-bytes fields are deterministic: the per-slot worst \
                 case reserves a full decode window of K/V per request, while the paged \
                 pool pins ceil(prompt/block_tokens) blocks plus one decode tail block \
                 (admission gated by SlotEngine::can_admit under the same byte budget); \
                 a warm prefix hit splices shared block handles instead of copying rows, \
                 so the pool's copied_rows counter stays zero (asserted here and in \
                 tests/kv_pool.rs); wall_* fields are host-dependent and filled in by \
                 `cargo bench --bench decode`, which overwrites this file",
            ),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_kv_pool.json");
    match std::fs::write(&path, format!("{out}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

/// Fused-vs-sequential decode sweep: one tick over {1, 2, 4, 8} active
/// slots, dense and full-FDB-student engines.  Sequential advances each
/// slot with its own `step_slot` (every linear re-streams its weight
/// matrix / CSC level stream once per slot); fused advances the same
/// rows with one batched `step_slots` call (each linear streamed once
/// per tick, batch innermost).  Both decode identical token streams —
/// the equivalence suite pins that — so this measures pure kernel
/// amortization.  Results land in `BENCH_fused_step.json`.
fn bench_fused_step(cfg: &ModelConfig, weights: &Weights, b: &mut Bench) {
    let window = cfg.seq_len;
    let mut fdb = BTreeMap::new();
    for name in cfg.linear_names() {
        fdb.insert(name.clone(), FdbLinear::from_weights(weights.mat(&name), 64));
    }
    let dense: BTreeMap<String, FdbLinear> = BTreeMap::new();
    let mut sweep = Vec::new();
    for &m in &[1usize, 2, 4, 8] {
        for (label, fdb_map) in [("dense", &dense), ("fdb", &fdb)] {
            // two engines so the timing loops never share ring state;
            // staggered prompt lengths put every slot at its own
            // position, as continuous batching does
            let mut seq =
                NativeEngine::new(weights.clone(), fdb_map, window, 42).with_slots(m);
            let mut fus =
                NativeEngine::new(weights.clone(), fdb_map, window, 42).with_slots(m);
            for slot in 0..m {
                let plen = 8 + 4 * slot;
                let prompt: Vec<u32> =
                    (0..plen as u32).map(|i| i % cfg.vocab as u32).collect();
                seq.prefill_slot(slot, &prompt).unwrap();
                fus.prefill_slot(slot, &prompt).unwrap();
            }
            let steps: Vec<(usize, u32)> = (0..m).map(|s| (s, 7u32)).collect();
            let ns_seq =
                b.bench_with_work(&format!("seq_step_{label}_m{m}"), Some(m as f64), || {
                    for &(slot, tok) in &steps {
                        black_box(seq.step_slot(slot, tok).unwrap());
                    }
                });
            let ns_fused =
                b.bench_with_work(&format!("fused_step_{label}_m{m}"), Some(m as f64), || {
                    black_box(fus.step_slots(&steps).unwrap());
                });
            sweep.push(Json::obj(vec![
                ("mode", Json::str(label)),
                ("slots", Json::num(m as f64)),
                ("wall_ns_per_tick_sequential", Json::num(ns_seq)),
                ("wall_ns_per_tick_fused", Json::num(ns_fused)),
                ("fused_speedup", Json::num(ns_seq / ns_fused)),
                // the deterministic work model: weight streams paid per
                // tick by each strategy
                ("weight_streams_per_tick_sequential", Json::num(m as f64)),
                ("weight_streams_per_tick_fused", Json::num(1.0)),
            ]));
        }
    }
    let out = Json::obj(vec![
        ("bench", Json::str("fused_step_slots")),
        ("model", Json::str(cfg.name.clone())),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("window", Json::num(window as f64)),
        ("slots_sweep", Json::Arr(vec![
            Json::num(1.0),
            Json::num(2.0),
            Json::num(4.0),
            Json::num(8.0),
        ])),
        ("sweep", Json::Arr(sweep)),
        (
            "note",
            // byte-identical to the committed BENCH_fused_step.json
            // note, so a bench run only churns the measured fields
            Json::str(
                "the weight-stream model is deterministic: sequential decode re-streams \
                 every linear's weight matrix (dense) or CSC level stream (FDB) once per \
                 active slot per tick, fused streams each exactly once per tick with the \
                 batch innermost; fused and sequential decode identical greedy streams \
                 (tests/fused_decode.rs pins bit-identical logits); wall_* and \
                 fused_speedup fields are host-dependent and filled in by \
                 `cargo bench --bench decode`, which overwrites this file",
            ),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_fused_step.json");
    match std::fs::write(&path, format!("{out}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

/// Mixed-length continuous-vs-static comparison: 12 requests with
/// budgets {4, 32, 128} interleaved in arrival order, 4 slots.
///
/// Two cost axes:
/// - **lockstep ticks** — what a batch-synchronous device pays: the
///   static batcher runs each arrival-order wave until its *slowest*
///   row finishes (finished rows stall in their slots), while the
///   continuous scheduler refills freed slots mid-flight.  These
///   counts are deterministic.
/// - **wall clock** — this host's CPU decode, where per-row work is
///   sequential either way, so the times mostly confirm the scheduler
///   adds no overhead.
fn bench_scheduler_mixed(cfg: &ModelConfig, weights: &Weights, b: &mut Bench) {
    const SLOTS: usize = 4;
    let budgets: Vec<usize> = [4usize, 32, 128].iter().copied().cycle().take(12).collect();
    let window = cfg.seq_len;
    let prompts: Vec<Vec<u32>> =
        (0..budgets.len()).map(|i| vec![(i % cfg.vocab) as u32, 3, 5]).collect();
    let params: Vec<DecodeParams> =
        budgets.iter().map(|&n| DecodeParams::greedy(n)).collect();
    let tokens: usize = budgets.iter().sum();

    // deterministic lockstep metrics for the static waves
    let mut ticks_static = 0usize;
    let mut stalled_static = 0usize;
    for wave in budgets.chunks(SLOTS) {
        let longest = wave.iter().copied().max().unwrap_or(0);
        ticks_static += longest;
        stalled_static += wave.iter().map(|&n| longest - n).sum::<usize>();
    }

    // one cold continuous drain for its deterministic tick metrics
    let engine = NativeEngine::new(weights.clone(), &BTreeMap::new(), window, 42)
        .with_slots(SLOTS);
    let sched_cfg = SchedulerConfig { slots: SLOTS, ..Default::default() };
    let mut sched = Scheduler::new(engine, ManualClock::default(), sched_cfg);
    let drain = |sched: &mut Scheduler<NativeEngine, ManualClock>| {
        for (p, d) in prompts.iter().zip(&params) {
            let job = Job { prompt: p.clone(), params: *d, timeout_ms: None, queued_for_ms: 0 };
            sched.submit(job);
        }
        let mut replies = 0usize;
        while !sched.is_idle() {
            replies += sched.tick().len();
        }
        assert_eq!(replies, prompts.len(), "every request answered exactly once");
    };
    drain(&mut sched);
    let ticks_continuous = sched.stats.ticks as usize;
    let busy = sched.stats.busy_slot_ticks as usize;
    assert_eq!(busy, tokens, "continuous slots never stall: busy ticks == tokens");

    // measured wall clock, same work each iteration
    let wall_cont =
        b.bench_with_work("continuous_mixed_4_32_128", Some(tokens as f64), || {
            drain(&mut sched);
        });
    let mut static_engine = NativeEngine::new(weights.clone(), &BTreeMap::new(), window, 42);
    let wall_static =
        b.bench_with_work("static_waves_mixed_4_32_128", Some(tokens as f64), || {
            for w in 0..prompts.len().div_ceil(SLOTS) {
                let lo = w * SLOTS;
                let hi = (lo + SLOTS).min(prompts.len());
                black_box(static_engine.generate(&prompts[lo..hi], &params[lo..hi]).unwrap());
            }
        });

    let out = Json::obj(vec![
        ("bench", Json::str("scheduler_mixed_lengths")),
        ("slots", Json::num(SLOTS as f64)),
        ("requests", Json::num(budgets.len() as f64)),
        ("lengths_cycle", Json::Arr(vec![Json::num(4.0), Json::num(32.0), Json::num(128.0)])),
        ("tokens", Json::num(tokens as f64)),
        ("ticks_static", Json::num(ticks_static as f64)),
        ("ticks_continuous", Json::num(ticks_continuous as f64)),
        ("stalled_row_steps_static", Json::num(stalled_static as f64)),
        ("stalled_row_steps_continuous", Json::num(0.0)),
        (
            "lockstep_speedup",
            Json::num(ticks_static as f64 / ticks_continuous.max(1) as f64),
        ),
        (
            "slot_occupancy_continuous",
            Json::num(busy as f64 / (ticks_continuous.max(1) * SLOTS) as f64),
        ),
        ("wall_ns_per_drain_continuous", Json::num(wall_cont)),
        ("wall_ns_per_drain_static", Json::num(wall_static)),
        ("wall_tokens_per_sec_continuous", Json::num(tokens as f64 * 1e9 / wall_cont)),
        ("wall_tokens_per_sec_static", Json::num(tokens as f64 * 1e9 / wall_static)),
        (
            "note",
            // byte-identical to the committed BENCH_scheduler.json
            // note, so a bench run only churns the measured fields
            Json::str(
                "tick-model fields are deterministic (FCFS, slot-order admission, one \
                 token per active slot per tick); wall_* fields are host-dependent and \
                 filled in by `cargo bench --bench decode`, which overwrites this file",
            ),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_scheduler.json");
    match std::fs::write(&path, format!("{out}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

/// Speculative decoding: FDB student drafts k tokens, the dense
/// teacher verifies all of them (plus the bonus row) in ONE batched
/// `step_rows` traversal per tick.
///
/// Two cost axes, both asserted against the decoder's own counters
/// before anything is timed:
/// - **teacher weight traversals** — plain greedy decode pays one
///   batched teacher traversal per emitted token per tick; the
///   speculative tick pays exactly one traversal per *group* and emits
///   `accepted + 1` tokens from it, so `verify_passes` (spec) vs
///   lockstep ticks (plain) is the deterministic saving.
/// - **teacher forwards saved** — every accepted draft is a token the
///   teacher never had to step for on its own: it rode along as one
///   verify row.  With every slot window-eligible the model is exact:
///   `drafted == accepted + rejected`, `drafted == bonus * k` (each
///   drafting group offers exactly k), step-phase emissions
///   `== accepted + bonus`, and `fallback_rows == 0`.
///
/// Greedy speculative output is bit-identical to teacher-only decode —
/// tests/spec_decode.rs pins that — so this measures pure speed, never
/// content.  Results land in `BENCH_spec_decode.json`.
fn bench_spec_decode(cfg: &ModelConfig, weights: &Weights, b: &mut Bench) {
    const SLOTS: usize = 4;
    const DECODE: usize = 16;
    const PROMPT: usize = 8;
    const K: usize = 3;
    let window = cfg.seq_len;
    let mut fdb = BTreeMap::new();
    for name in cfg.linear_names() {
        fdb.insert(name.clone(), FdbLinear::from_weights(weights.mat(&name), 64));
    }
    // same seed for teacher and student: the student is the faithful
    // FDB compression of the teacher, as served in production (a junk
    // student would only shift the acceptance rate, never the streams)
    let mut spec =
        SpecDecoder::new(weights.clone(), weights.clone(), &fdb, window, K).with_slots(SLOTS);
    let prompts: Vec<Vec<u32>> = (0..SLOTS as u32)
        .map(|s| (0..PROMPT as u32).map(|t| (t * 3 + s * 11) % cfg.vocab as u32).collect())
        .collect();

    // one full greedy drain: every slot decodes until it has emitted
    // >= DECODE tokens, consuming every verify row of every group so
    // the work model stays exact; returns (step-phase emissions, ticks)
    let drain_spec = |spec: &mut SpecDecoder| -> (usize, usize) {
        let mut last = vec![0u32; SLOTS];
        let mut emitted = vec![0usize; SLOTS];
        for (slot, p) in prompts.iter().enumerate() {
            spec.reset_slot(slot);
            let logits = spec.prefill_slot(slot, p).unwrap();
            last[slot] = argmax(&logits) as u32;
            emitted[slot] = 1;
        }
        let mut ticks = 0usize;
        loop {
            let live: Vec<(usize, u32)> =
                (0..SLOTS).filter(|&s| emitted[s] < DECODE).map(|s| (s, last[s])).collect();
            if live.is_empty() {
                break;
            }
            ticks += 1;
            let groups = spec.step_slots_speculative(&live).unwrap();
            for (i, g) in groups.iter().enumerate() {
                let slot = live[i].0;
                for row in &g.rows {
                    last[slot] = argmax(row) as u32;
                    emitted[slot] += 1;
                }
            }
        }
        (emitted.iter().sum::<usize>() - SLOTS, ticks)
    };
    // the teacher-only baseline: same prompts, same per-slot token
    // count, one fused step_slots traversal per lockstep tick
    let mut plain =
        NativeEngine::new(weights.clone(), &BTreeMap::new(), window, 42).with_slots(SLOTS);
    let drain_plain = |plain: &mut NativeEngine| -> usize {
        let mut last = vec![0u32; SLOTS];
        for (slot, p) in prompts.iter().enumerate() {
            plain.reset_slot(slot);
            let logits = plain.prefill_slot(slot, p).unwrap();
            last[slot] = argmax(&logits) as u32;
        }
        for _ in 0..DECODE - 1 {
            let steps: Vec<(usize, u32)> = (0..SLOTS).map(|s| (s, last[s])).collect();
            let rows = plain.step_slots(&steps).unwrap();
            for (s, row) in rows.iter().enumerate() {
                last[s] = argmax(row) as u32;
            }
        }
        DECODE - 1
    };

    // deterministic pass: drain once cold and pin the work model
    // against the decoder's counters before any timing runs
    let before = spec.counters();
    let (emitted, spec_ticks) = drain_spec(&mut spec);
    let c = spec.counters();
    let drafted = (c.drafted - before.drafted) as usize;
    let accepted = (c.accepted - before.accepted) as usize;
    let rejected = (c.rejected - before.rejected) as usize;
    let bonus = (c.bonus - before.bonus) as usize;
    let verify_passes = (c.verify_passes - before.verify_passes) as usize;
    let rolled_back = (c.rolled_back_rows - before.rolled_back_rows) as usize;
    let fallback = (c.fallback_rows - before.fallback_rows) as usize;
    assert_eq!(drafted, accepted + rejected, "every draft is accepted or rejected");
    assert_eq!(fallback, 0, "all slots stay window-eligible at this geometry");
    assert_eq!(drafted, bonus * K, "every drafting group offers exactly k drafts");
    assert_eq!(emitted, accepted + bonus, "each group emits accepted + 1 tokens");
    assert_eq!(verify_passes, spec_ticks, "one batched teacher traversal per tick");
    assert_eq!(
        spec.kv_pool().stats().copied_rows,
        0,
        "speculative rollback truncates block tables, never copies rows"
    );
    let teacher_forwards_saved = accepted;
    let plain_ticks = drain_plain(&mut plain);
    assert!(
        spec_ticks <= plain_ticks,
        "a speculative tick always emits >= 1 token, so it never needs more \
         ticks than plain decode ({spec_ticks} vs {plain_ticks})"
    );

    // measured wall clock: one full drain per iteration, both modes
    let spec_tokens = emitted + SLOTS;
    let plain_tokens = SLOTS * DECODE;
    let ns_spec = b.bench_with_work("spec_decode_drain", Some(spec_tokens as f64), || {
        black_box(drain_spec(&mut spec));
    });
    let ns_plain = b.bench_with_work("teacher_only_drain", Some(plain_tokens as f64), || {
        black_box(drain_plain(&mut plain));
    });
    let ns_per_tok_spec = ns_spec / spec_tokens as f64;
    let ns_per_tok_plain = ns_plain / plain_tokens as f64;

    let out = Json::obj(vec![
        ("bench", Json::str("spec_decode")),
        ("model", Json::str(cfg.name.clone())),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("window", Json::num(window as f64)),
        ("slots", Json::num(SLOTS as f64)),
        ("k", Json::num(K as f64)),
        ("prompt_tokens", Json::num(PROMPT as f64)),
        ("decode_tokens_per_slot", Json::num(DECODE as f64)),
        ("drafted", Json::num(drafted as f64)),
        ("accepted", Json::num(accepted as f64)),
        ("rejected", Json::num(rejected as f64)),
        ("bonus_tokens", Json::num(bonus as f64)),
        ("fallback_rows", Json::num(fallback as f64)),
        ("rolled_back_rows", Json::num(rolled_back as f64)),
        ("acceptance_rate", Json::num(accepted as f64 / drafted.max(1) as f64)),
        ("teacher_forwards_saved", Json::num(teacher_forwards_saved as f64)),
        ("verify_passes", Json::num(verify_passes as f64)),
        ("ticks_speculative", Json::num(spec_ticks as f64)),
        ("ticks_teacher_only", Json::num(plain_ticks as f64)),
        ("tick_reduction", Json::num(1.0 - spec_ticks as f64 / plain_ticks.max(1) as f64)),
        ("wall_ns_per_token_speculative", Json::num(ns_per_tok_spec)),
        ("wall_ns_per_token_teacher_only", Json::num(ns_per_tok_plain)),
        ("wall_speculative_speedup", Json::num(ns_per_tok_plain / ns_per_tok_spec)),
        (
            "note",
            // byte-identical to the committed BENCH_spec_decode.json
            // note, so a bench run only churns the measured fields
            Json::str(
                "the draft/accept model is deterministic: every drafting group offers \
                 exactly k student drafts, the teacher verifies them plus the bonus row \
                 in one batched step_rows traversal, and each accepted draft is a token \
                 the teacher never stepped for on its own (teacher_forwards_saved == \
                 accepted), all asserted against SpecCounters before timing; greedy \
                 speculative streams are bit-identical to teacher-only decode \
                 (tests/spec_decode.rs pins this across seeds, rollback at block \
                 boundaries, and mid-flight refills); wall_* fields are host-dependent \
                 and filled in by `cargo bench --bench decode`, which overwrites this \
                 file",
            ),
        ),
    ]);
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_spec_decode.json");
    match std::fs::write(&path, format!("{out}\n")) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
