//! §Perf L3 hot path: the bit-serial FDB matmul (Eq. 8) vs the dense
//! dequantized matmul — the measured realization of Table 6's
//! "bitwise ops + sparsity reduce computation ~20% vs 2-bit" claim.
//!
//!     cargo bench --bench fdb_matmul        (BENCH_QUICK=1 for smoke)

use db_llm::quant::FdbLinear;
use db_llm::tensor::Matrix;
use db_llm::util::bench::{black_box, Bench};
use db_llm::util::Pcg32;

fn main() {
    let mut b = Bench::new("fdb_matmul");
    let mut rng = Pcg32::seeded(1);

    for &(m, k, n) in &[(8usize, 256usize, 256usize), (8, 704, 256), (64, 256, 704)] {
        let w = Matrix::randn(k, n, &mut rng, 1.0);
        let fdb = FdbLinear::from_weights(&w, 64);
        let w_hat = fdb.dequant();
        let x = Matrix::randn(m, k, &mut rng, 1.0);
        let flops = (2 * m * k * n) as f64;

        b.bench_with_work(&format!("dense_dequant_{m}x{k}x{n}"), Some(flops), || {
            black_box(x.matmul(&w_hat));
        });
        b.bench_with_work(&format!("bit_serial_{m}x{k}x{n}"), Some(flops), || {
            black_box(fdb.matmul(&x));
        });
        // §Perf v2: compiled CSC execution form (decode cached per layer)
        let exec = db_llm::quant::kernel::FdbExec::compile(&fdb);
        b.bench_with_work(&format!("fdb_exec_{m}x{k}x{n}"), Some(flops), || {
            black_box(exec.matmul(&x));
        });
        b.bench_with_work(&format!("compile_{m}x{k}x{n}"), Some((k * n) as f64), || {
            black_box(db_llm::quant::kernel::FdbExec::compile(&fdb));
        });
    }

    // sparsity scaling: bit-serial cost must fall as planes get sparser
    for &density in &[0.9f32, 0.5, 0.25, 0.1] {
        let (k, n, m) = (512usize, 512usize, 8usize);
        let plane = Matrix::from_fn(k, n, |_, _| if rng.f32() < density { 1.0 } else { 0.0 });
        let fdb = FdbLinear {
            din: k,
            dout: n,
            group: 64,
            b1: db_llm::quant::packing::BitPlane::pack(&plane),
            b2: db_llm::quant::packing::BitPlane::pack(&plane),
            a1: Matrix::from_fn(k / 64, n, |_, _| 1.0),
            a2: Matrix::from_fn(k / 64, n, |_, _| -0.5),
        };
        let x = Matrix::randn(m, k, &mut rng, 1.0);
        b.bench(&format!("bit_serial_density_{:.0}pct", density * 100.0), || {
            black_box(fdb.matmul(&x));
        });
    }

    b.report();
}
