//! Entropy-codec benchmarks: Huffman/RLE throughput over packed FDB
//! planes and the realized effective-bits measurement (§3.2's ≈1.88-bit
//! claim machinery).

use db_llm::codec::{self, huffman, rle};
use db_llm::quant::FdbLinear;
use db_llm::tensor::Matrix;
use db_llm::util::bench::{black_box, Bench};
use db_llm::util::Pcg32;

fn main() {
    let mut b = Bench::new("codec");
    let mut rng = Pcg32::seeded(3);

    let w = Matrix::randn(704, 256, &mut rng, 1.0);
    let fdb = FdbLinear::from_weights(&w, 64);
    let bytes1 = fdb.b1.to_bytes();
    let n = bytes1.len() as f64;

    b.bench_with_work("huffman_encode_plane", Some(n), || {
        black_box(huffman::encode(&bytes1));
    });
    let enc = huffman::encode(&bytes1);
    b.bench_with_work("huffman_decode_plane", Some(n), || {
        black_box(huffman::decode(&enc).unwrap());
    });
    b.bench_with_work("rle_encode_plane", Some(n), || {
        black_box(rle::encode(&bytes1));
    });
    b.bench_with_work("effective_bits_layer", Some(n * 2.0), || {
        black_box(codec::effective_bits(&fdb));
    });
    b.bench_with_work("pack_plane", Some((704 * 256) as f64), || {
        black_box(db_llm::quant::packing::BitPlane::pack(&fdb.b1.unpack()));
    });

    // print the measured storage numbers alongside the throughput
    let eb = codec::effective_bits(&fdb);
    println!(
        "\nmeasured: plane bits {:.3}, scale bits {:.3}, total {:.3} (shannon floor {:.3})",
        eb.plane_bits, eb.scale_bits, eb.total, eb.shannon_floor
    );
    b.report();
}
