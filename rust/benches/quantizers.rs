//! Quantizer engine micro-benchmarks: cost of each method on a
//! realistic layer shape (the XL teacher's largest linears) — the
//! "cost of the compression process" axis the paper argues weight-only
//! PTQ wins on.

use db_llm::quant::{
    awq::Awq, fdb::Fdb, gptq::Gptq, omniquant::OmniQuant, pbllm::PbLlm, rtn::Rtn, Calib,
    Quantizer,
};
use db_llm::tensor::Matrix;
use db_llm::util::bench::{black_box, Bench};
use db_llm::util::Pcg32;

fn main() {
    let mut b = Bench::new("quantizers");
    let mut rng = Pcg32::seeded(2);
    let (din, dout) = (256usize, 704usize); // XL w_gate/w_up shape
    let w = Matrix::randn(din, dout, &mut rng, 0.04);
    let calib = Calib::new(Matrix::randn(512, din, &mut rng, 1.0));
    let weights = (din * dout) as f64;

    let methods: Vec<(&str, Box<dyn Quantizer>)> = vec![
        ("rtn_w2", Box::new(Rtn::new(2, 64))),
        ("rtn_w3", Box::new(Rtn::new(3, 64))),
        ("gptq_w2", Box::new(Gptq::new(2, 64))),
        ("awq_w2", Box::new(Awq::new(2, 64))),
        ("omniquant_w2", Box::new(OmniQuant::new(2, 64))),
        ("pbllm", Box::new(PbLlm::new(64))),
        ("fdb", Box::new(Fdb { group: 64 })),
    ];
    for (name, q) in &methods {
        b.bench_with_work(&format!("{name}_{din}x{dout}"), Some(weights), || {
            black_box(q.quantize(&w, &calib));
        });
    }

    // GPTQ substrate: the Hessian Cholesky path
    b.bench("hessian_inv_chol_256", || {
        black_box(calib.hessian_inv_chol(0.01).unwrap());
    });

    b.report();
}
