//! End-to-end table regeneration harness: one bench entry per paper
//! table/figure (DESIGN.md §5).  Unlike the micro-benches this runs the
//! real pipelines at reduced window counts and times them — `cargo
//! bench --bench tables` regenerates every row the paper reports and
//! prints the wall-clock budget of each.
//!
//! Control with env vars:
//!   TABLES=1,3,6      subset (default: all of 1,2,3,4,5,6,7)
//!   FIGURES=1,3,4,6,7 subset (default: all)
//!   WINDOWS=48        ppl windows per cell
//!   ZS_ITEMS=80       zero-shot items per suite

use db_llm::eval::tables::{self, TableOpts};
use db_llm::runtime::Runtime;

fn env_list(name: &str, default: &[&str]) -> Vec<String> {
    std::env::var(name)
        .map(|v| v.split(',').map(str::to_string).collect())
        .unwrap_or_else(|_| default.iter().map(|s| s.to_string()).collect())
}

fn main() -> anyhow::Result<()> {
    let mut rt = Runtime::open("artifacts")?;
    let mut opts = TableOpts::default();
    if let Ok(w) = std::env::var("WINDOWS") {
        opts.windows = w.parse().unwrap_or(opts.windows);
    } else {
        opts.windows = 32;
    }
    if let Ok(z) = std::env::var("ZS_ITEMS") {
        opts.zs_items = z.parse().unwrap_or(opts.zs_items);
    } else {
        opts.zs_items = 48;
    }
    opts.dad_batches = 24;

    let tables_sel = env_list("TABLES", &["1", "2", "3", "4", "5", "6", "7"]);
    let figures_sel = env_list("FIGURES", &["1", "3", "4", "6", "7"]);

    let mut budget = Vec::new();
    for id in &tables_sel {
        let t0 = std::time::Instant::now();
        match id.as_str() {
            "1" => drop(tables::table_ppl(&mut rt, &opts, false)?),
            "2" => drop(tables::table_ppl(&mut rt, &opts, true)?),
            "3" => drop(tables::table3(&mut rt, &opts)?),
            "4" => drop(tables::table4(&mut rt, &opts)?),
            "5" => drop(tables::table_zeroshot(&mut rt, &opts, false)?),
            "6" => drop(tables::table6(&mut rt, &opts)?),
            "7" => drop(tables::table_zeroshot(&mut rt, &opts, true)?),
            other => eprintln!("skipping unknown table {other}"),
        }
        budget.push((format!("table{id}"), t0.elapsed()));
    }
    for id in &figures_sel {
        let t0 = std::time::Instant::now();
        match id.as_str() {
            "1" => drop(tables::figure1(&mut rt, &opts)?),
            "3" => drop(tables::figure3(&mut rt, &opts)?),
            "4" => drop(tables::figure4(&mut rt, &opts)?),
            "6" => drop(tables::figure6(&mut rt, &opts)?),
            "7" => drop(tables::figure7(&mut rt, &opts)?),
            other => eprintln!("skipping unknown figure {other}"),
        }
        budget.push((format!("figure{id}"), t0.elapsed()));
    }

    println!("\n== regeneration wall-clock ==");
    for (name, d) in budget {
        println!("{name:<10} {:.1}s", d.as_secs_f64());
    }
    Ok(())
}
